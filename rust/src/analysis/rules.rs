//! The lint rules. Each is a token-sequence pattern over [`FileFacts`],
//! grounded in a written contract elsewhere in the repo — the rule docs
//! below name the contract, docs/analysis.md carries the catalog.
//!
//! Rules are plain functions over lexed facts so the fixture tests can
//! drive them on inline snippets; scoping (which files each rule applies
//! to) lives in [`super::run`].

use super::lexer::{FileFacts, Kind};
use super::Finding;

pub const PANIC_SURFACE: &str = "panic-surface";
pub const PARITY: &str = "parity";
pub const DETERMINISM: &str = "determinism";
pub const SCHEMA: &str = "schema";
/// Meta-rule: `lazylint: allow(...)` comments must be well-formed and
/// carry a reason. Not suppressible.
pub const ALLOW_REASON: &str = "allow-reason";

/// Keywords that may legitimately precede `[` (slice patterns, types);
/// an identifier *not* in this set followed by `[` is an indexing site.
const KEYWORDS: &[&str] = &[
    "as", "box", "break", "const", "continue", "crate", "dyn", "else", "enum", "fn", "for", "if",
    "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref", "return", "static",
    "struct", "trait", "type", "use", "where", "while",
];

fn finding(rule: &'static str, path: &str, line: usize, msg: String) -> Finding {
    Finding {
        rule,
        path: path.to_string(),
        line,
        msg,
    }
}

/// **panic-surface** — the deterministic-failure-routing contract (PR 1 /
/// PR 7; ARCHITECTURE.md §The event-driven serve loop): connection and
/// actor threads route malformed input and racing channels into error
/// replies, never into a thread-killing panic. Flags, in non-test code:
/// `.unwrap()` / `.expect(...)`, `panic!(...)`, and direct slice indexing
/// (`x[i]`, `f()[i]`, `x[i][j]` — an out-of-bounds index panics exactly
/// like an unwrap).
pub fn panic_surface(f: &FileFacts) -> Vec<Finding> {
    let mut out = Vec::new();
    let toks = &f.toks;
    for (i, t) in f.code_toks() {
        let prev = i.checked_sub(1).and_then(|p| toks.get(p));
        if t.kind == Kind::Ident && (t.text == "unwrap" || t.text == "expect") {
            if prev.map_or(false, |p| p.is(Kind::Punct, ".")) {
                out.push(finding(
                    PANIC_SURFACE,
                    &f.path,
                    t.line,
                    format!(".{}() can panic the serving thread — route the failure or annotate an allow", t.text),
                ));
            }
        } else if t.is(Kind::Ident, "panic")
            && toks.get(i + 1).map_or(false, |n| n.is(Kind::Punct, "!"))
        {
            out.push(finding(
                PANIC_SURFACE,
                &f.path,
                t.line,
                "panic!() in serving-path code — return an error instead".to_string(),
            ));
        } else if t.is(Kind::Punct, "[") {
            let is_index = match prev {
                Some(p) if p.kind == Kind::Ident => !KEYWORDS.contains(&p.text.as_str()),
                Some(p) if p.kind == Kind::Punct => p.text == ")" || p.text == "]",
                _ => false,
            };
            if is_index {
                out.push(finding(
                    PANIC_SURFACE,
                    &f.path,
                    t.line,
                    "direct slice indexing panics out-of-bounds — use .get()/.get_mut() or annotate an allow".to_string(),
                ));
            }
        }
    }
    out
}

/// **determinism** — replayability contracts: the simulator and the router
/// must be pure functions of their inputs (`sim/`, `scheduler/routing.rs`
/// — seeded tie-breaks, no wall clock), the serve/actor loops are
/// event-driven, not sleep-polled (the PR 7 condvar contract), and nothing
/// that feeds ordered output may iterate a `HashMap` (iteration order is
/// randomized per process).
pub fn determinism(
    f: &FileFacts,
    time_scope: bool,
    sleep_scope: bool,
    hashmap_scope: bool,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let toks = &f.toks;
    let seq = |i: usize, pats: &[(Kind, &str)]| {
        pats.iter()
            .enumerate()
            .all(|(k, (kind, text))| toks.get(i + k).map_or(false, |t| !t.in_test && t.kind == *kind && t.text == *text))
    };
    if time_scope {
        for (i, t) in f.code_toks() {
            if t.is(Kind::Ident, "Instant")
                && seq(i, &[(Kind::Ident, "Instant"), (Kind::Punct, ":"), (Kind::Punct, ":"), (Kind::Ident, "now")])
            {
                out.push(finding(DETERMINISM, &f.path, t.line,
                    "Instant::now() in replay/routing code breaks run-to-run determinism".to_string()));
            }
            if t.is(Kind::Ident, "SystemTime") {
                out.push(finding(DETERMINISM, &f.path, t.line,
                    "SystemTime in replay/routing code breaks run-to-run determinism".to_string()));
            }
        }
    }
    if sleep_scope {
        for (i, t) in f.code_toks() {
            if t.is(Kind::Ident, "thread")
                && seq(i, &[(Kind::Ident, "thread"), (Kind::Punct, ":"), (Kind::Punct, ":"), (Kind::Ident, "sleep")])
            {
                out.push(finding(DETERMINISM, &f.path, t.line,
                    "thread::sleep in a serve/actor loop — use condvar/channel wakeups (PR 7 contract) or annotate an allow".to_string()));
            }
        }
    }
    if hashmap_scope {
        out.extend(hashmap_iteration(f));
    }
    out
}

/// Iteration over an identifier that was declared as a `HashMap`
/// (`name: HashMap<...>` or `name = HashMap::new()`): `.iter()`, `.keys()`
/// and friends, or a `for _ in name` loop.
fn hashmap_iteration(f: &FileFacts) -> Vec<Finding> {
    let toks = &f.toks;
    let mut names: Vec<String> = Vec::new();
    for (i, t) in f.code_toks() {
        if t.is(Kind::Ident, "HashMap") {
            // `name : HashMap` (binding or field type annotation)
            if let (Some(p2), Some(p1)) = (i.checked_sub(2).and_then(|k| toks.get(k)), i.checked_sub(1).and_then(|k| toks.get(k))) {
                if p1.is(Kind::Punct, ":") && p2.kind == Kind::Ident && !names.contains(&p2.text) {
                    names.push(p2.text.clone());
                }
                // `name = HashMap::new()`
                if p1.is(Kind::Punct, "=") && p2.kind == Kind::Ident && !names.contains(&p2.text) {
                    names.push(p2.text.clone());
                }
            }
        }
    }
    const ITERS: &[&str] = &["iter", "iter_mut", "into_iter", "keys", "values", "values_mut", "drain"];
    let mut out = Vec::new();
    for (i, t) in f.code_toks() {
        if t.kind != Kind::Ident || !names.contains(&t.text) {
            continue;
        }
        // name . iter ( … )
        if toks.get(i + 1).map_or(false, |d| d.is(Kind::Punct, "."))
            && toks.get(i + 2).map_or(false, |m| m.kind == Kind::Ident && ITERS.contains(&m.text.as_str()))
        {
            out.push(finding(DETERMINISM, &f.path, t.line,
                format!("`{}` is a HashMap — .{}() has randomized order; collect+sort or use an ordered structure", t.text, toks[i + 2].text)));
        }
        // for _ in [&[mut]] name
        let mut back = i;
        while back > 0 && toks.get(back - 1).map_or(false, |p| p.is(Kind::Punct, "&") || p.is(Kind::Ident, "mut")) {
            back -= 1;
        }
        if back > 0 && toks.get(back - 1).map_or(false, |p| p.is(Kind::Ident, "in")) {
            out.push(finding(DETERMINISM, &f.path, t.line,
                format!("`for … in {}` iterates a HashMap in randomized order", t.text)));
        }
    }
    out
}

/// Inputs the **parity** rule needs beyond one file.
pub struct ParityInputs<'a> {
    /// Every lexed file under `rust/src` (metric-literal scan).
    pub code: &'a [FileFacts],
    /// `main.rs` (flag parse sites).
    pub main: Option<&'a FileFacts>,
    /// `metrics/mod.rs` (`PoolGauges` struct vs `fields()`).
    pub metrics: Option<&'a FileFacts>,
    /// `telemetry/flight.rs` (`mod event` constants).
    pub flight: Option<&'a FileFacts>,
    /// `telemetry/span.rs` (`mod name` constants — each publishes a
    /// constructed `lazyeviction_span_<name>_ms` histogram).
    pub span: Option<&'a FileFacts>,
    pub observability_md: &'a str,
    pub serving_md: &'a str,
}

/// **parity** — docs/observability.md §"One source of truth": every
/// `lazyeviction_*` metric name in code appears in docs/observability.md
/// and vice versa (pool gauges via the `lazyeviction_pool_<…>` wildcard),
/// every flag `main.rs` parses appears in docs/serving.md, every flight
/// event name appears in docs/observability.md, and the `PoolGauges`
/// struct fields match the `PoolGauges::fields()` publish list exactly.
pub fn parity(inp: &ParityInputs) -> Vec<Finding> {
    let mut out = Vec::new();

    // --- metric names, both directions -----------------------------------
    // code side: string literals + the constructed pool-gauge names
    let mut code_metrics: Vec<(String, String, usize)> = Vec::new(); // (name, path, line)
    for f in inp.code {
        for (_, t) in f.code_toks() {
            if t.kind == Kind::Str && is_metric_name(&t.text) {
                if !code_metrics.iter().any(|(n, _, _)| n == &t.text) {
                    code_metrics.push((t.text.clone(), f.path.clone(), t.line));
                }
            }
        }
    }
    let (struct_fields, fields_literals) = inp
        .metrics
        .map(pool_gauge_sets)
        .unwrap_or_default();
    for (name, line) in &struct_fields {
        let full = format!("lazyeviction_pool_{name}");
        if !code_metrics.iter().any(|(n, _, _)| n == &full) {
            let path = inp.metrics.map(|m| m.path.clone()).unwrap_or_default();
            code_metrics.push((full, path, *line));
        }
    }
    // span duration histograms are constructed (`lazyeviction_span_<name>_ms`
    // via `span::metric_name`), never literal — synthesize one per `mod name`
    // constant so the doc check covers them like any other metric
    if let Some(span) = inp.span {
        for (lit, line) in span_mod_literals(span) {
            let full = format!("lazyeviction_span_{lit}_ms");
            if !code_metrics.iter().any(|(n, _, _)| n == &full) {
                code_metrics.push((full, span.path.clone(), line));
            }
        }
    }
    // docs side: names and `<…>` wildcard prefixes, with their lines
    let (doc_names, doc_prefixes) = doc_metric_names(inp.observability_md);
    for (name, path, line) in &code_metrics {
        let documented = doc_names.iter().any(|(n, _)| n == name)
            || doc_prefixes.iter().any(|p| name.starts_with(p.as_str()));
        if !documented {
            out.push(finding(PARITY, path, *line,
                format!("metric `{name}` is published but not documented in docs/observability.md")));
        }
    }
    for (name, line) in &doc_names {
        if !code_metrics.iter().any(|(n, _, _)| n == name) {
            out.push(finding(PARITY, "docs/observability.md", *line,
                format!("metric `{name}` is documented but nothing in rust/src publishes it")));
        }
    }

    // --- PoolGauges struct vs fields() -----------------------------------
    if let Some(m) = inp.metrics {
        for (name, line) in &struct_fields {
            if !fields_literals.iter().any(|(n, _)| n == name) {
                out.push(finding(PARITY, &m.path, *line,
                    format!("PoolGauges field `{name}` is missing from PoolGauges::fields() — it will never be published")));
            }
        }
        for (name, line) in &fields_literals {
            if !struct_fields.iter().any(|(n, _)| n == name) {
                out.push(finding(PARITY, &m.path, *line,
                    format!("PoolGauges::fields() publishes `{name}` but the struct has no such field")));
            }
        }
    }

    // --- flags: main.rs parse sites → docs/serving.md --------------------
    if let Some(main) = inp.main {
        for (name, line) in flag_parse_sites(main) {
            if !inp.serving_md.contains(&format!("--{name}")) {
                out.push(finding(PARITY, &main.path, line,
                    format!("flag `--{name}` is parsed but not documented in docs/serving.md")));
            }
        }
    }

    // --- flight events → docs/observability.md ---------------------------
    if let Some(flight) = inp.flight {
        for (name, line) in event_mod_literals(flight) {
            if !inp.observability_md.contains(&format!("`{name}`")) {
                out.push(finding(PARITY, &flight.path, line,
                    format!("flight event `{name}` is not documented in docs/observability.md")));
            }
        }
    }
    out
}

/// A full metric name: `lazyeviction_` + at least one more segment, not a
/// bare prefix (trailing `_` marks a prefix constant like `POOL_PREFIX`).
fn is_metric_name(s: &str) -> bool {
    s.strip_prefix("lazyeviction_").map_or(false, |rest| {
        !rest.is_empty()
            && !rest.ends_with('_')
            && rest.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
    })
}

/// Scan a markdown document for `lazyeviction_…` mentions. Returns
/// (full names with lines, wildcard prefixes — `lazyeviction_pool_<gauge>`
/// contributes the prefix `lazyeviction_pool_`).
fn doc_metric_names(md: &str) -> (Vec<(String, usize)>, Vec<String>) {
    let mut names = Vec::new();
    let mut prefixes = Vec::new();
    for (ln, line) in md.lines().enumerate() {
        let mut rest = line;
        while let Some(at) = rest.find("lazyeviction_") {
            let tail = &rest[at..];
            let end = tail
                .char_indices()
                .find(|(_, c)| !(c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_'))
                .map(|(i, _)| i)
                .unwrap_or(tail.len());
            let tok = &tail[..end];
            if tail[end..].starts_with('<') && tok.len() > "lazyeviction_".len() {
                // wildcard family like `lazyeviction_pool_<counter>`; the
                // bare namespace mention (`prefixed lazyeviction_`) is NOT
                // a wildcard — it would make the code→docs check vacuous
                if !prefixes.iter().any(|p| p == tok) {
                    prefixes.push(tok.to_string());
                }
            } else if is_metric_name(tok) && !names.iter().any(|(n, _)| n == tok) {
                names.push((tok.to_string(), ln + 1));
            }
            rest = &rest[at + end.max(1)..];
        }
    }
    (names, prefixes)
}

/// (`PoolGauges` struct field names, `fields()` string literals), each
/// with a line number.
fn pool_gauge_sets(f: &FileFacts) -> (Vec<(String, usize)>, Vec<(String, usize)>) {
    let toks = &f.toks;
    let mut fields = Vec::new();
    if let Some(body) = brace_region(f, &["struct", "PoolGauges"]) {
        let mut i = body.0;
        while i < body.1 {
            // `pub name :` at struct depth
            if toks[i].is(Kind::Ident, "pub")
                && toks.get(i + 1).map_or(false, |t| t.kind == Kind::Ident)
                && toks.get(i + 2).map_or(false, |t| t.is(Kind::Punct, ":"))
            {
                fields.push((toks[i + 1].text.clone(), toks[i + 1].line));
                i += 3;
            } else {
                i += 1;
            }
        }
    }
    let mut lits = Vec::new();
    if let Some(body) = brace_region(f, &["fn", "fields"]) {
        for t in &toks[body.0..body.1] {
            if t.kind == Kind::Str && is_plain_key(&t.text) && !lits.iter().any(|(n, _): &(String, usize)| n == &t.text) {
                lits.push((t.text.clone(), t.line));
            }
        }
    }
    (fields, lits)
}

/// `args.<parser>("name")` sites in main.rs — the receiver must literally
/// be `args` (the CLI parse handle), which keeps JSON `.get("…")` calls
/// out of the flag set.
fn flag_parse_sites(f: &FileFacts) -> Vec<(String, usize)> {
    const PARSERS: &[&str] = &["usize_or", "str_or", "f64_or", "u64_or", "bool_flag", "get", "has"];
    let toks = &f.toks;
    let mut out: Vec<(String, usize)> = Vec::new();
    for (i, t) in f.code_toks() {
        if t.is(Kind::Ident, "args")
            && toks.get(i + 1).map_or(false, |d| d.is(Kind::Punct, "."))
            && toks.get(i + 2).map_or(false, |m| m.kind == Kind::Ident && PARSERS.contains(&m.text.as_str()))
            && toks.get(i + 3).map_or(false, |p| p.is(Kind::Punct, "("))
            && toks.get(i + 4).map_or(false, |s| s.kind == Kind::Str)
        {
            let name = toks[i + 4].text.clone();
            if !out.iter().any(|(n, _)| n == &name) {
                out.push((name, toks[i + 4].line));
            }
        }
    }
    out
}

/// String literals inside `pub mod event { … }` — the flight event names.
fn event_mod_literals(f: &FileFacts) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    if let Some(body) = brace_region(f, &["mod", "event"]) {
        for t in &f.toks[body.0..body.1] {
            if t.kind == Kind::Str && is_plain_key(&t.text) {
                out.push((t.text.clone(), t.line));
            }
        }
    }
    out
}

/// String literals inside `pub mod name { … }` of telemetry/span.rs — the
/// span names whose duration histograms the registry publishes.
fn span_mod_literals(f: &FileFacts) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    if let Some(body) = brace_region(f, &["mod", "name"]) {
        for t in &f.toks[body.0..body.1] {
            if t.kind == Kind::Str && is_plain_key(&t.text) {
                out.push((t.text.clone(), t.line));
            }
        }
    }
    out
}

/// **schema** — bench_harness/report.rs is the `BENCH_pool.json` contract
/// (docs/observability.md §BENCH_pool.json): every key `validate()`
/// requires must be a key `to_json()` serializes (a one-sided rename
/// would make every CI report fail — or never be checked), and every
/// report field `benches/pool.rs` fills must be a serialized key.
pub fn schema(report: &FileFacts, bench: Option<&FileFacts>) -> Vec<Finding> {
    let mut out = Vec::new();
    // serialized keys: `.set("key", …)` anywhere in non-test report code
    let toks = &report.toks;
    let mut set_keys: Vec<String> = Vec::new();
    for (i, t) in report.code_toks() {
        if t.is(Kind::Ident, "set")
            && i > 0
            && toks[i - 1].is(Kind::Punct, ".")
            && toks.get(i + 1).map_or(false, |p| p.is(Kind::Punct, "("))
            && toks.get(i + 2).map_or(false, |s| s.kind == Kind::Str)
        {
            let k = toks[i + 2].text.clone();
            if !set_keys.contains(&k) {
                set_keys.push(k);
            }
        }
    }
    // required keys: ident-like string literals inside fn validate
    if let Some(body) = brace_region(report, &["fn", "validate"]) {
        for t in &report.toks[body.0..body.1] {
            if t.kind == Kind::Str && is_plain_key(&t.text) && !set_keys.contains(&t.text) {
                out.push(finding(SCHEMA, &report.path, t.line,
                    format!("validate() requires key `{}` but to_json() never serializes it", t.text)));
            }
        }
    }
    // bench side: struct-literal fields of the report types must be
    // serialized keys (a field rename that misses to_json shows up here)
    if let Some(b) = bench {
        for ty in ["BenchScenario", "FleetCell", "RecurrenceCell"] {
            for (name, line) in struct_literal_fields(b, ty) {
                if !set_keys.contains(&name) {
                    out.push(finding(SCHEMA, &b.path, line,
                        format!("benches fill `{ty}.{name}` but report.rs to_json() has no `{name}` key")));
                }
            }
        }
    }
    out
}

/// Field idents of every `Type { field: …, … }` struct literal for `ty`.
fn struct_literal_fields(f: &FileFacts, ty: &str) -> Vec<(String, usize)> {
    let toks = &f.toks;
    let mut out: Vec<(String, usize)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].in_test
            && toks[i].is(Kind::Ident, ty)
            && toks.get(i + 1).map_or(false, |t| t.is(Kind::Punct, "{"))
        {
            let mut depth = 0usize;
            let mut j = i + 1;
            while j < toks.len() {
                if toks[j].is(Kind::Punct, "{") {
                    depth += 1;
                } else if toks[j].is(Kind::Punct, "}") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if depth == 1
                    && toks[j].kind == Kind::Ident
                    && toks.get(j + 1).map_or(false, |t| t.is(Kind::Punct, ":"))
                    && !toks.get(j + 2).map_or(false, |t| t.is(Kind::Punct, ":"))
                    && toks.get(j - 1).map_or(false, |t| t.is(Kind::Punct, "{") || t.is(Kind::Punct, ","))
                {
                    if !out.iter().any(|(n, _)| n == &toks[j].text) {
                        out.push((toks[j].text.clone(), toks[j].line));
                    }
                }
                j += 1;
            }
            i = j;
        }
        i += 1;
    }
    out
}

/// `^[a-z0-9_]+$` — what a JSON key / metric field / event name looks
/// like; error-message literals (spaces, braces) never match.
fn is_plain_key(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Token span (start, end) of the brace-matched body following the first
/// non-test occurrence of the ident sequence `intro` (e.g. `["fn",
/// "validate"]`). End is exclusive of the closing brace.
fn brace_region(f: &FileFacts, intro: &[&str]) -> Option<(usize, usize)> {
    let toks = &f.toks;
    let mut i = 0usize;
    'outer: while i < toks.len() {
        for (k, want) in intro.iter().enumerate() {
            match toks.get(i + k) {
                Some(t) if !t.in_test && t.is(Kind::Ident, want) => {}
                _ => {
                    i += 1;
                    continue 'outer;
                }
            }
        }
        // found the intro; advance to the first `{`
        let mut j = i + intro.len();
        while j < toks.len() && !toks[j].is(Kind::Punct, "{") {
            j += 1;
        }
        let mut depth = 0usize;
        let start = j + 1;
        while j < toks.len() {
            if toks[j].is(Kind::Punct, "{") {
                depth += 1;
            } else if toks[j].is(Kind::Punct, "}") {
                depth -= 1;
                if depth == 0 {
                    return Some((start, j));
                }
            }
            j += 1;
        }
        return Some((start, toks.len()));
    }
    None
}
