//! `lazylint` — the repo's own static-analysis pass.
//!
//! Eight PRs of pool/tier/fleet machinery rest on invariants that until
//! now existed only as prose: the deterministic-failure-routing contract
//! on the serving threads, doc/metric/flag parity, the no-sleep-poll
//! serve-loop contract, simulator determinism, and the `BENCH_pool.json`
//! schema. This module turns each into a mechanical check over a lexed
//! token stream ([`lexer`]) so violations fail CI instead of waiting for
//! a reviewer. The rule catalog, scoping and suppression syntax are
//! documented in docs/analysis.md; ARCHITECTURE.md §Static analysis maps
//! each rule to the contract it enforces. The *dynamic* counterpart —
//! runtime invariants a lexer cannot see — is [`crate::kvpool::audit`].
//!
//! Zero dependencies by construction: the lexer is hand-rolled (no
//! crates.io in this environment), rules are token-sequence patterns, and
//! the whole pass runs from a plain binary (`cargo run --release --bin
//! lazylint -- rust/src docs`).
//!
//! ## Suppressions
//!
//! `// lazylint: allow(<rule>): <reason>` on the offending line or the
//! line directly above suppresses that rule there. The reason is
//! mandatory — an allow without one (or a malformed control comment) is
//! itself a finding (`allow-reason`), so every suppression in the tree
//! carries its justification next to the code it excuses.

pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use lexer::FileFacts;

/// One lint finding: rule, repo-relative location, message.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(w, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Run every rule over the tree. `rust_src` is the crate source root
/// (`rust/src`), `docs` the documentation directory; `rust/benches` is
/// found relative to `rust_src`. Returns the surviving findings, sorted
/// by (path, line). IO problems (unreadable tree) come back as `Err` so
/// the binary can distinguish "findings" from "could not run".
pub fn run(rust_src: &Path, docs: &Path) -> Result<Vec<Finding>, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(rust_src, &mut files)?;
    files.sort();
    let mut facts: Vec<FileFacts> = Vec::new();
    for p in &files {
        let rel = p
            .strip_prefix(rust_src)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        facts.push(FileFacts::lex(&rel, &src));
    }
    // the bench driver lives outside src/ but inside the contracts
    let bench_path = rust_src
        .parent()
        .map(|r| r.join("benches").join("pool.rs"))
        .filter(|p| p.is_file());
    let bench_facts = match &bench_path {
        Some(p) => {
            let src = fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
            Some(FileFacts::lex("benches/pool.rs", &src))
        }
        None => None,
    };
    let observability_md = read_doc(docs, "observability.md");
    let serving_md = read_doc(docs, "serving.md");

    let mut findings: Vec<Finding> = Vec::new();
    if observability_md.is_empty() {
        findings.push(Finding {
            rule: rules::PARITY,
            path: "docs/observability.md".into(),
            line: 0,
            msg: "docs/observability.md missing or empty — metric/event parity cannot hold".into(),
        });
    }
    if serving_md.is_empty() {
        findings.push(Finding {
            rule: rules::PARITY,
            path: "docs/serving.md".into(),
            line: 0,
            msg: "docs/serving.md missing or empty — flag parity cannot hold".into(),
        });
    }

    for f in &facts {
        if panic_surface_scope(&f.path) {
            findings.extend(apply_suppressions(f, rules::panic_surface(f)));
        }
        let d = rules::determinism(
            f,
            time_scope(&f.path),
            sleep_scope(&f.path),
            hashmap_scope(&f.path),
        );
        findings.extend(apply_suppressions(f, d));
        findings.extend(control_comment_findings(f));
    }
    if let Some(b) = &bench_facts {
        let d = rules::determinism(b, false, false, true);
        findings.extend(apply_suppressions(b, d));
        findings.extend(control_comment_findings(b));
    }

    let inputs = rules::ParityInputs {
        code: &facts,
        main: facts.iter().find(|f| f.path == "main.rs"),
        metrics: facts.iter().find(|f| f.path == "metrics/mod.rs"),
        flight: facts.iter().find(|f| f.path == "telemetry/flight.rs"),
        span: facts.iter().find(|f| f.path == "telemetry/span.rs"),
        observability_md: &observability_md,
        serving_md: &serving_md,
    };
    findings.extend(suppress_by_path(&facts, rules::parity(&inputs)));
    if let Some(report) = facts.iter().find(|f| f.path == "bench_harness/report.rs") {
        findings.extend(suppress_by_path(&facts, rules::schema(report, bench_facts.as_ref())));
    }

    findings.sort_by(|a, b| (a.path.as_str(), a.line).cmp(&(b.path.as_str(), b.line)));
    Ok(findings)
}

/// The serving-path files under the deterministic-failure-routing
/// contract (ISSUE scope: `server/`, the actor, the telemetry listener,
/// the wire layer).
fn panic_surface_scope(path: &str) -> bool {
    path.starts_with("server/")
        || path == "coordinator/actor.rs"
        || path == "telemetry/http.rs"
        || path == "util/wire.rs"
}

/// Replay/routing determinism: the simulator and the router are pure
/// functions of their seeds.
fn time_scope(path: &str) -> bool {
    path.starts_with("sim/") || path == "scheduler/routing.rs"
}

/// The PR 7 condvar contract: no sleep-polling in serve/actor loops.
fn sleep_scope(path: &str) -> bool {
    path.starts_with("server/") || path == "coordinator/actor.rs"
}

/// Ordered-output paths that must not iterate a `HashMap`.
fn hashmap_scope(path: &str) -> bool {
    path == "scheduler/routing.rs" || path.starts_with("benches/")
}

/// Drop findings covered by a well-formed, reasoned `allow` on the same
/// line or the line above. Reason-less and malformed allows never
/// suppress (they are reported separately by
/// [`control_comment_findings`]).
fn apply_suppressions(f: &FileFacts, found: Vec<Finding>) -> Vec<Finding> {
    found
        .into_iter()
        .filter(|x| {
            !f.suppressions.iter().any(|s| {
                !s.malformed
                    && !s.reason.is_empty()
                    && s.rule == x.rule
                    && (s.line == x.line || s.line + 1 == x.line)
            })
        })
        .collect()
}

/// Cross-file rules (parity, schema) anchor findings to whichever file
/// owns the offending token; route each finding through that file's
/// suppressions.
fn suppress_by_path(facts: &[FileFacts], found: Vec<Finding>) -> Vec<Finding> {
    found
        .into_iter()
        .filter(|x| match facts.iter().find(|f| f.path == x.path) {
            Some(f) => !f.suppressions.iter().any(|s| {
                !s.malformed
                    && !s.reason.is_empty()
                    && s.rule == x.rule
                    && (s.line == x.line || s.line + 1 == x.line)
            }),
            None => true,
        })
        .collect()
}

/// The meta-rule: every `lazylint:` control comment must be well-formed
/// and carry a reason.
fn control_comment_findings(f: &FileFacts) -> Vec<Finding> {
    let mut out = Vec::new();
    for s in &f.suppressions {
        if s.malformed {
            out.push(Finding {
                rule: rules::ALLOW_REASON,
                path: f.path.clone(),
                line: s.line,
                msg: format!("malformed lazylint control comment ({})", s.reason),
            });
        } else if s.reason.is_empty() {
            out.push(Finding {
                rule: rules::ALLOW_REASON,
                path: f.path.clone(),
                line: s.line,
                msg: format!(
                    "allow({}) needs a reason: `// lazylint: allow({}): <why this is safe>`",
                    s.rule, s.rule
                ),
            });
        }
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in rd {
        let entry = entry.map_err(|e| e.to_string())?;
        let p = entry.path();
        if p.is_dir() {
            // vendored shims are out of scope (separate crates, excluded
            // from the contracts and from #![forbid(unsafe_code)] alike)
            if p.file_name().map_or(false, |n| n == "vendor") {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().map_or(false, |e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn read_doc(docs: &Path, name: &str) -> String {
    fs::read_to_string(docs.join(name)).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::lexer::FileFacts;
    use super::rules;

    fn lex(path: &str, src: &str) -> FileFacts {
        FileFacts::lex(path, src)
    }

    // ---- rule 1: panic-surface ------------------------------------------

    #[test]
    fn panic_surface_fires_on_each_seeded_violation() {
        let bad = lex(
            "server/mod.rs",
            "fn f(v: Vec<u32>, i: usize) -> u32 {\n    let a = v.get(0).unwrap();\n    let b = v.first().expect(\"x\");\n    if i > 9 { panic!(\"boom\"); }\n    v[i] + a + b\n}\n",
        );
        let hits = rules::panic_surface(&bad);
        assert_eq!(hits.len(), 4, "unwrap, expect, panic!, indexing: {hits:?}");
        assert_eq!(hits[0].line, 2);
        assert_eq!(hits[1].line, 3);
        assert_eq!(hits[2].line, 4);
        assert_eq!(hits[3].line, 5);
    }

    #[test]
    fn panic_surface_is_quiet_on_the_good_snippet() {
        let good = lex(
            "server/mod.rs",
            "fn f(v: &[u32], i: usize) -> Option<u32> {\n    // unwrap_or_else and arrays-in-types are not findings\n    let d: [u8; 4] = [0; 4];\n    let x = v.get(i).copied().unwrap_or_default();\n    let y = vec![1, 2][..].first().copied().unwrap_or(0);\n    Some(x + y + d.len() as u32)\n}\n",
        );
        let hits: Vec<_> = rules::panic_surface(&good);
        // `vec![1, 2][..]` *is* slicing of a macro result — prev token `]`
        let slicing: Vec<_> = hits.iter().filter(|h| h.line == 5).collect();
        assert_eq!(hits.len(), slicing.len(), "only the real slice remains: {hits:?}");
    }

    #[test]
    fn panic_surface_skips_test_code_and_reasoned_allows() {
        let f = lex(
            "server/mod.rs",
            "fn live(v: &[u32]) -> u32 {\n    // lazylint: allow(panic-surface): index bounded by the loop above\n    v[0]\n}\n#[cfg(test)]\nmod tests {\n    fn t(v: Vec<u32>) { v.clone().pop().unwrap(); }\n}\n",
        );
        let hits = super::apply_suppressions(&f, rules::panic_surface(&f));
        assert!(hits.is_empty(), "{hits:?}");
        assert!(super::control_comment_findings(&f).is_empty());
    }

    #[test]
    fn reasonless_allow_is_reported_and_does_not_suppress() {
        let f = lex(
            "server/mod.rs",
            "fn live(v: &[u32]) -> u32 {\n    // lazylint: allow(panic-surface)\n    v[0]\n}\n",
        );
        let hits = super::apply_suppressions(&f, rules::panic_surface(&f));
        assert_eq!(hits.len(), 1, "reason-less allow must not suppress");
        let meta = super::control_comment_findings(&f);
        assert_eq!(meta.len(), 1);
        assert_eq!(meta[0].rule, rules::ALLOW_REASON);
    }

    // ---- rule 3: determinism --------------------------------------------

    #[test]
    fn determinism_fires_on_clock_sleep_and_hashmap_iteration() {
        let f = lex(
            "sim/thing.rs",
            "use std::time::Instant;\nfn f() {\n    let t = Instant::now();\n    let s = SystemTime::now();\n    std::thread::sleep(d);\n    let m: HashMap<u64, u32> = HashMap::new();\n    for (k, v) in &m { emit(k, v); }\n    let ks: Vec<_> = m.keys().collect();\n}\n",
        );
        let hits = rules::determinism(&f, true, true, true);
        let lines: Vec<usize> = hits.iter().map(|h| h.line).collect();
        assert!(lines.contains(&3), "Instant::now: {hits:?}");
        assert!(lines.contains(&4), "SystemTime: {hits:?}");
        assert!(lines.contains(&5), "thread::sleep: {hits:?}");
        assert!(lines.contains(&7), "for-in HashMap: {hits:?}");
        assert!(lines.contains(&8), ".keys(): {hits:?}");
    }

    #[test]
    fn determinism_is_quiet_on_keyed_hashmap_access_and_out_of_scope_clocks() {
        let f = lex(
            "scheduler/routing.rs",
            "fn f() {\n    let mut m: HashMap<u64, u32> = HashMap::new();\n    m.insert(1, 2);\n    let _ = m.get(&1);\n    m.clear();\n}\n",
        );
        assert!(rules::determinism(&f, true, true, true).is_empty());
        // Instant in a file outside the time scope is not a finding
        let g = lex("metrics/mod.rs", "fn f() { let t = Instant::now(); }");
        assert!(rules::determinism(&g, false, false, false).is_empty());
    }

    // ---- rule 2: parity --------------------------------------------------

    #[test]
    fn parity_fires_on_each_seeded_drift() {
        let code = vec![lex(
            "telemetry/mod.rs",
            "pub const A: &str = \"lazyeviction_documented_total\";\npub const B: &str = \"lazyeviction_undocumented_total\";\n",
        )];
        let main = lex(
            "main.rs",
            "fn f(args: &Args) { let _ = args.usize_or(\"documented-flag\", 1); let _ = args.str_or(\"ghost-flag\", \"\"); }",
        );
        let metrics = lex(
            "metrics/mod.rs",
            "pub struct PoolGauges { pub free_blocks: u64, pub ghost_field: u64 }\nimpl PoolGauges { pub fn fields(&self) -> V { vec![(\"free_blocks\", 0.0)] } }\n",
        );
        let flight = lex(
            "telemetry/flight.rs",
            "pub mod event { pub const A: &str = \"queued\"; pub const B: &str = \"ghost_event\"; }",
        );
        let span = lex(
            "telemetry/span.rs",
            "pub mod name { pub const A: &str = \"request\"; pub const B: &str = \"ghost_span\"; }",
        );
        let obs = "| `lazyeviction_documented_total` | x |\n| `lazyeviction_phantom_total` | y |\n| `queued` | z |\n| `lazyeviction_span_request_ms` | s |\n";
        let serving = "`--documented-flag N` does things\n";
        let hits = rules::parity(&rules::ParityInputs {
            code: &code,
            main: Some(&main),
            metrics: Some(&metrics),
            flight: Some(&flight),
            span: Some(&span),
            observability_md: obs,
            serving_md: serving,
        });
        let msgs: Vec<&str> = hits.iter().map(|h| h.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("lazyeviction_undocumented_total")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("lazyeviction_phantom_total")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("--ghost-flag")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("ghost_event")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("ghost_field")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("lazyeviction_span_ghost_span_ms")), "{msgs:?}");
        // the documented halves stay quiet
        assert!(!msgs.iter().any(|m| m.contains("`lazyeviction_documented_total`")), "{msgs:?}");
        assert!(!msgs.iter().any(|m| m.contains("--documented-flag")), "{msgs:?}");
        assert!(!msgs.iter().any(|m| m.contains("`lazyeviction_span_request_ms`")), "{msgs:?}");
    }

    #[test]
    fn parity_is_quiet_when_code_and_docs_agree() {
        let code = vec![lex(
            "telemetry/mod.rs",
            "pub const A: &str = \"lazyeviction_x_total\";\npub const P: &str = \"lazyeviction_pool_\";\n",
        )];
        let metrics = lex(
            "metrics/mod.rs",
            "pub struct PoolGauges { pub free_blocks: u64 }\nimpl PoolGauges { pub fn fields(&self) -> V { vec![(\"free_blocks\", 0.0)] } }\n",
        );
        let obs = "All metrics are prefixed `lazyeviction_`.\n| `lazyeviction_x_total` | x |\n| `lazyeviction_pool_<gauge>` | pool |\n";
        let hits = rules::parity(&rules::ParityInputs {
            code: &code,
            main: None,
            metrics: Some(&metrics),
            flight: None,
            span: None,
            observability_md: obs,
            serving_md: "",
        });
        assert!(hits.is_empty(), "{hits:?}");
    }

    // ---- rule 4: schema --------------------------------------------------

    #[test]
    fn schema_fires_on_a_validate_key_to_json_never_writes() {
        let report = lex(
            "bench_harness/report.rs",
            "impl R {\n    pub fn to_json(&self) -> Json { Json::obj().set(\"steps\", 1).set(\"completed\", 2) }\n    pub fn validate(j: &Json) -> Result<(), String> {\n        j.get(\"steps\").ok_or(\"missing steps count\")?;\n        j.get(\"renamed_field\").ok_or(\"missing value\")?;\n        Ok(())\n    }\n}\n",
        );
        let hits = rules::schema(&report, None);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].msg.contains("renamed_field"));
    }

    #[test]
    fn schema_checks_bench_struct_literals_and_stays_quiet_when_aligned() {
        let report = lex(
            "bench_harness/report.rs",
            "impl R {\n    pub fn to_json(&self) -> Json { Json::obj().set(\"steps\", 1).set(\"policy\", 2) }\n    pub fn validate(j: &Json) -> Result<(), String> { j.get(\"steps\").ok_or(\"missing steps count\")?; Ok(()) }\n}\n",
        );
        let good_bench = lex(
            "benches/pool.rs",
            "fn main() { r.push(BenchScenario { steps: 1, policy: p.into() }); }",
        );
        assert!(rules::schema(&report, Some(&good_bench)).is_empty());
        let bad_bench = lex(
            "benches/pool.rs",
            "fn main() { r.push(BenchScenario { steps: 1, stale_name: 2 }); }",
        );
        let hits = rules::schema(&report, Some(&bad_bench));
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].msg.contains("stale_name"));
    }
}
