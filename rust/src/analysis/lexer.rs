//! A small hand-rolled Rust lexer — just enough structure for the lint
//! rules in [`super::rules`]: a token stream with line numbers, string
//! literals separated from code, comments stripped (except `lazylint:`
//! control comments, which are parsed into [`Suppression`]s), and
//! `#[cfg(test)]` regions marked so rules can skip test code.
//!
//! This is *not* a Rust parser. It recognizes exactly the lexical shapes
//! the rules need to be sound on this codebase: line and nested block
//! comments, plain/raw/byte string literals with escapes, char literals vs
//! lifetimes, identifiers, numbers, and single-character punctuation. No
//! crates.io access in this environment, so no `syn` — and none needed:
//! every rule is a token-sequence pattern, not a semantic query.

/// What a token is, as far as the rules care.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`unwrap`, `let`, `HashMap`, …).
    Ident,
    /// String literal; `text` is the raw content between the quotes
    /// (escapes left unprocessed — the rules match literal names, which
    /// never contain escapes).
    Str,
    /// Char literal (`'a'`, `'\n'`).
    Char,
    /// Lifetime (`'a` in type position).
    Life,
    /// Numeric literal.
    Num,
    /// One punctuation character; `text` is that character.
    Punct,
}

/// One token: kind, source text, 1-based line, and whether it sits inside
/// a `#[cfg(test)]` item (attribute + brace-matched body).
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
    pub in_test: bool,
}

impl Tok {
    pub fn is(&self, kind: Kind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

/// A `// lazylint: allow(<rule>): <reason>` control comment. It applies to
/// findings on its own line and on the line directly below (so it can sit
/// on its own line above the offending statement).
#[derive(Clone, Debug)]
pub struct Suppression {
    pub line: usize,
    pub rule: String,
    /// Non-empty human justification after the closing paren. A
    /// suppression without one is itself reported (`allow-reason`).
    pub reason: String,
    /// Malformed control comment (bad `allow(...)` shape); reported.
    pub malformed: bool,
}

/// One lexed file: the token stream plus the control comments found in it.
#[derive(Clone, Debug, Default)]
pub struct FileFacts {
    /// Repo-relative path, `/`-separated (rules scope on suffixes of it).
    pub path: String,
    pub toks: Vec<Tok>,
    pub suppressions: Vec<Suppression>,
}

impl FileFacts {
    /// Lex `src`. `path` is kept verbatim for scoping and reporting.
    pub fn lex(path: &str, src: &str) -> FileFacts {
        let mut f = FileFacts {
            path: path.to_string(),
            ..FileFacts::default()
        };
        let b: Vec<char> = src.chars().collect();
        let mut i = 0usize;
        let mut line = 1usize;
        while i < b.len() {
            let c = b[i];
            if c == '\n' {
                line += 1;
                i += 1;
            } else if c.is_whitespace() {
                i += 1;
            } else if c == '/' && b.get(i + 1) == Some(&'/') {
                let start = i + 2;
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                if let Some(s) = parse_control(text.trim(), line) {
                    f.suppressions.push(s);
                }
            } else if c == '/' && b.get(i + 1) == Some(&'*') {
                // nested block comments, line counting preserved
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            } else if c == '"' {
                let (text, ni, nl) = lex_string(&b, i + 1, line);
                f.push(Kind::Str, text, line);
                line = nl;
                i = ni;
            } else if c == 'b'
                && b.get(i + 1) == Some(&'"')
                && !matches!(b.get(i.wrapping_sub(1)), Some(p) if p.is_alphanumeric() || *p == '_')
            {
                // byte string: escaped like a plain string, `b` prefix
                let (text, ni, nl) = lex_string(&b, i + 2, line);
                f.push(Kind::Str, text, line);
                line = nl;
                i = ni;
            } else if is_raw_string_start(&b, i) {
                let (text, ni, nl) = lex_raw_string(&b, i, line);
                f.push(Kind::Str, text, line);
                line = nl;
                i = ni;
            } else if c == '\'' {
                // char literal vs lifetime: a lifetime is `'ident` not
                // followed by a closing quote; everything else (escapes,
                // single chars) closes with `'`
                let (kind, text, ni) = lex_quote(&b, i);
                f.push(kind, text, line);
                i = ni;
            } else if c.is_alphabetic() || c == '_' {
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                f.push(Kind::Ident, b[start..i].iter().collect(), line);
            } else if c.is_ascii_digit() {
                let start = i;
                // numbers (incl. hex/underscores/float tails); a trailing
                // `.` followed by an ident is a method call, not a float
                while i < b.len()
                    && (b[i].is_alphanumeric()
                        || b[i] == '_'
                        || (b[i] == '.'
                            && b.get(i + 1).is_some_and_digit()))
                {
                    i += 1;
                }
                f.push(Kind::Num, b[start..i].iter().collect(), line);
            } else {
                f.push(Kind::Punct, c.to_string(), line);
                i += 1;
            }
        }
        mark_test_regions(&mut f.toks);
        f
    }

    fn push(&mut self, kind: Kind, text: String, line: usize) {
        self.toks.push(Tok {
            kind,
            text,
            line,
            in_test: false,
        });
    }

    /// Iterator over non-test tokens (what most rules scan).
    pub fn code_toks(&self) -> impl Iterator<Item = (usize, &Tok)> {
        self.toks
            .iter()
            .enumerate()
            .filter(|(_, t)| !t.in_test)
    }
}

/// Whether `i` starts a *raw* string: `r"`, `r#"`, `br#"`, …. Plain `b"`
/// byte strings are escaped and handled by [`lex_string`] instead.
fn is_raw_string_start(b: &[char], i: usize) -> bool {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while b.get(j) == Some(&'#') {
        j += 1;
    }
    b.get(j) == Some(&'"') && !matches!(b.get(i.wrapping_sub(1)), Some(c) if c.is_alphanumeric() || *c == '_')
}

/// Lex a plain (possibly byte-prefixed) string body starting *after* the
/// opening quote. Returns (content, next index, next line).
fn lex_string(b: &[char], mut i: usize, mut line: usize) -> (String, usize, usize) {
    let start = i;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '"' => break,
            '\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    let text: String = b[start..i.min(b.len())].iter().collect();
    (text, (i + 1).min(b.len()), line)
}

/// Lex a raw string starting at its prefix (`r`, `br`, …). No escapes;
/// terminated by `"` followed by the same number of `#`s it opened with.
fn lex_raw_string(b: &[char], mut i: usize, mut line: usize) -> (String, usize, usize) {
    if b.get(i) == Some(&'b') {
        i += 1;
    }
    i += 1; // the `r`
    let mut hashes = 0usize;
    while b.get(i) == Some(&'#') {
        hashes += 1;
        i += 1;
    }
    i += 1; // the opening quote
    let start = i;
    while i < b.len() {
        if b[i] == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if b[i] == '"' {
            let mut ok = true;
            for k in 0..hashes {
                if b.get(i + 1 + k) != Some(&'#') {
                    ok = false;
                    break;
                }
            }
            if ok {
                let text: String = b[start..i].iter().collect();
                return (text, i + 1 + hashes, line);
            }
        }
        i += 1;
    }
    (b[start.min(b.len())..].iter().collect(), b.len(), line)
}

/// Lex from a `'`: char literal (closes with `'`) or lifetime.
fn lex_quote(b: &[char], i: usize) -> (Kind, String, usize) {
    // escape: always a char literal
    if b.get(i + 1) == Some(&'\\') {
        let mut j = i + 2;
        while j < b.len() && b[j] != '\'' {
            j += 1;
        }
        return (Kind::Char, b[i + 1..j.min(b.len())].iter().collect(), (j + 1).min(b.len()));
    }
    // 'x' — a single char then a closing quote
    if b.get(i + 2) == Some(&'\'') {
        let text = b.get(i + 1).map(|c| c.to_string()).unwrap_or_default();
        return (Kind::Char, text, i + 3);
    }
    // lifetime: 'ident (no closing quote)
    let mut j = i + 1;
    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
        j += 1;
    }
    (Kind::Life, b[i + 1..j].iter().collect(), j)
}

/// Parse a line comment body into a control comment, if it is one.
/// Syntax: `lazylint: allow(<rule>): <reason>`.
fn parse_control(text: &str, line: usize) -> Option<Suppression> {
    let rest = text.strip_prefix("lazylint:")?.trim();
    let bad = |why: &str| Suppression {
        line,
        rule: String::new(),
        reason: why.to_string(),
        malformed: true,
    };
    let Some(inner) = rest.strip_prefix("allow(") else {
        return Some(bad("expected `allow(<rule>)`"));
    };
    let Some(close) = inner.find(')') else {
        return Some(bad("unclosed `allow(`"));
    };
    let rule = inner[..close].trim().to_string();
    if rule.is_empty() || !rule.chars().all(|c| c.is_ascii_lowercase() || c == '-') {
        return Some(bad("rule name must be kebab-case"));
    }
    let tail = inner[close + 1..].trim();
    let reason = tail.strip_prefix(':').unwrap_or("").trim().to_string();
    Some(Suppression {
        line,
        rule,
        reason,
        malformed: false,
    })
}

/// Mark every token belonging to a `#[cfg(test)]` item: the attribute
/// itself, any further attributes, and the brace-matched body of the item
/// that follows (`mod tests { … }`, a single `#[cfg(test)] fn`, …).
fn mark_test_regions(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if is_cfg_test_at(toks, i) {
            // find the item body: first `{` after the attribute, then its
            // matching `}` (items introduced by cfg(test) in this tree are
            // always brace-delimited modules or functions)
            let attr_start = i;
            let mut j = i + 7; // past `# [ cfg ( test ) ]`
            while j < toks.len() && !toks[j].is(Kind::Punct, "{") {
                j += 1;
            }
            let mut depth = 0usize;
            let mut end = j;
            while end < toks.len() {
                if toks[end].is(Kind::Punct, "{") {
                    depth += 1;
                } else if toks[end].is(Kind::Punct, "}") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                end += 1;
            }
            for t in toks[attr_start..(end + 1).min(toks.len())].iter_mut() {
                t.in_test = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
}

/// `# [ cfg ( test ) ]` starting at token `i`.
fn is_cfg_test_at(toks: &[Tok], i: usize) -> bool {
    let want: [(&Kind, &str); 7] = [
        (&Kind::Punct, "#"),
        (&Kind::Punct, "["),
        (&Kind::Ident, "cfg"),
        (&Kind::Punct, "("),
        (&Kind::Ident, "test"),
        (&Kind::Punct, ")"),
        (&Kind::Punct, "]"),
    ];
    want.iter()
        .enumerate()
        .all(|(k, (kind, text))| toks.get(i + k).map_or(false, |t| t.kind == **kind && t.text == *text))
}

/// Tiny helper so the number lexer reads cleanly.
trait IsDigit {
    fn is_some_and_digit(&self) -> bool;
}
impl IsDigit for Option<&char> {
    fn is_some_and_digit(&self) -> bool {
        self.map_or(false, |c| c.is_ascii_digit())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_comments_and_idents() {
        let f = FileFacts::lex(
            "x.rs",
            "let s = \"lazyeviction_x\"; // plain comment\nlet t = r#\"raw \"quoted\" text\"#;",
        );
        let strs: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, vec!["lazyeviction_x", "raw \"quoted\" text"]);
        assert!(f.suppressions.is_empty(), "plain comments are not control comments");
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let f = FileFacts::lex("x.rs", r#"let s = "a\"b"; let u = s.unwrap();"#);
        let s = f.toks.iter().find(|t| t.kind == Kind::Str).unwrap();
        assert_eq!(s.text, "a\\\"b");
        assert!(f.toks.iter().any(|t| t.is(Kind::Ident, "unwrap")));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let f = FileFacts::lex("x.rs", "fn f<'a>(x: &'a str) { let c = '\\n'; let d = ']'; }");
        let lifes = f.toks.iter().filter(|t| t.kind == Kind::Life).count();
        let chars = f.toks.iter().filter(|t| t.kind == Kind::Char).count();
        assert_eq!(lifes, 2);
        assert_eq!(chars, 2);
        // the `]` char literal must not register as punctuation
        assert!(!f.toks.iter().any(|t| t.is(Kind::Punct, "]") && t.line == 1 && t.text == "]" && t.kind == Kind::Punct
            && f.toks.iter().filter(|u| u.is(Kind::Punct, "]")).count() > 1));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() { a.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { b.unwrap(); }\n}\nfn live2() {}";
        let f = FileFacts::lex("x.rs", src);
        let unwraps: Vec<bool> = f
            .toks
            .iter()
            .filter(|t| t.is(Kind::Ident, "unwrap"))
            .map(|t| t.in_test)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
        assert!(f.toks.iter().any(|t| t.is(Kind::Ident, "live2") && !t.in_test));
    }

    #[test]
    fn control_comments_parse() {
        let src = "// lazylint: allow(panic-surface): bounded by construction\nx[0];\n// lazylint: allow(determinism)\n// lazylint: nonsense\n";
        let f = FileFacts::lex("x.rs", src);
        assert_eq!(f.suppressions.len(), 3);
        assert_eq!(f.suppressions[0].rule, "panic-surface");
        assert_eq!(f.suppressions[0].reason, "bounded by construction");
        assert!(!f.suppressions[0].malformed);
        assert_eq!(f.suppressions[1].rule, "determinism");
        assert!(f.suppressions[1].reason.is_empty(), "missing reason is recorded as empty");
        assert!(f.suppressions[2].malformed);
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let f = FileFacts::lex("x.rs", "/* a /* nested */ b\nc */ ident_after");
        let t = f.toks.iter().find(|t| t.is(Kind::Ident, "ident_after")).unwrap();
        assert_eq!(t.line, 2, "block comment newlines must advance the line counter");
    }
}
