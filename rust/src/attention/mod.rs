//! Recurrence Interval Tracking (paper §4, Eq. 1).
//!
//! Every decode step the engine receives one aggregated attention score per
//! live slot. `observe` applies the RaaS-style timestamp rule and the
//! LazyEviction MRI update to the slot records:
//!
//! ```text
//! if attn[i] >= alpha:  MRI_t[i] = max(MRI_{t-1}[i], t - TS_{t-1}[i])
//!                       TS_t[i]  = t
//! ```
//!
//! plus the bookkeeping other baselines need (last/cumulative attention,
//! hit counts). One pass, O(live).

use crate::kvcache::TokenRecord;

/// Tracking hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TrackerConfig {
    /// Importance threshold α (paper: 1e-4..1e-3 depending on model; our
    /// aggregated scores are max-over-heads so the same scale applies).
    pub alpha: f32,
}

impl Default for TrackerConfig {
    fn default() -> Self {
        TrackerConfig { alpha: 5e-4 }
    }
}

/// Apply one step of attention observation to the live records.
/// `attn[i]` is the aggregated attention for slot i; `step` is the absolute
/// decoding step (same clock as TokenRecord.ts).
pub fn observe(records: &mut [TokenRecord], attn: &[f32], step: u32, cfg: TrackerConfig) {
    debug_assert!(attn.len() >= records.len());
    for (rec, &a) in records.iter_mut().zip(attn.iter()) {
        rec.last_attn = a;
        rec.cum_attn += a;
        if a >= cfg.alpha {
            // Eq. 1: interval since the previous important step
            let interval = step.saturating_sub(rec.ts);
            if interval > rec.mri {
                rec.mri = interval;
            }
            rec.ts = step;
            rec.hits += 1;
        }
    }
}

/// Elapsed time since last importance (Δt in the H1 score).
#[inline]
pub fn elapsed(rec: &TokenRecord, step: u32) -> u32 {
    step.saturating_sub(rec.ts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pos: u32) -> TokenRecord {
        TokenRecord::new(pos, pos)
    }

    #[test]
    fn below_alpha_only_accumulates() {
        let mut rs = vec![rec(0)];
        observe(&mut rs, &[1e-6], 5, TrackerConfig { alpha: 1e-3 });
        assert_eq!(rs[0].ts, 0);
        assert_eq!(rs[0].mri, 0);
        assert_eq!(rs[0].hits, 0);
        assert!((rs[0].cum_attn - 1e-6).abs() < 1e-12);
        assert!((rs[0].last_attn - 1e-6).abs() < 1e-12);
    }

    #[test]
    fn activation_updates_ts_and_mri() {
        let cfg = TrackerConfig { alpha: 0.1 };
        let mut rs = vec![rec(0)];
        observe(&mut rs, &[0.5], 4, cfg); // interval 4-0=4
        assert_eq!(rs[0].ts, 4);
        assert_eq!(rs[0].mri, 4);
        observe(&mut rs, &[0.5], 6, cfg); // interval 2 < 4 → mri stays
        assert_eq!(rs[0].ts, 6);
        assert_eq!(rs[0].mri, 4);
        observe(&mut rs, &[0.5], 16, cfg); // interval 10 > 4 → mri grows
        assert_eq!(rs[0].mri, 10);
        assert_eq!(rs[0].hits, 3);
    }

    #[test]
    fn eq1_matches_paper_semantics() {
        // MRI_t = max(MRI_{t-1}, TS_t - TS_{t-1}) — only on activations
        let cfg = TrackerConfig { alpha: 0.01 };
        let mut rs = vec![rec(10)]; // born (TS=10)
        for (t, a) in [(12, 0.0), (13, 0.9), (20, 0.9), (21, 0.001)] {
            observe(&mut rs, &[a], t, cfg);
        }
        // activations at 13 (interval 3) and 20 (interval 7)
        assert_eq!(rs[0].mri, 7);
        assert_eq!(rs[0].ts, 20);
    }

    #[test]
    fn never_activated_keeps_mri_zero() {
        let cfg = TrackerConfig { alpha: 0.5 };
        let mut rs = vec![rec(0)];
        for t in 1..50 {
            observe(&mut rs, &[0.01], t, cfg);
        }
        assert_eq!(rs[0].mri, 0);
        assert_eq!(elapsed(&rs[0], 49), 49);
    }

    #[test]
    fn multiple_slots_independent() {
        let cfg = TrackerConfig { alpha: 0.1 };
        let mut rs = vec![rec(0), rec(1), rec(2)];
        observe(&mut rs, &[0.9, 0.0, 0.9], 5, cfg);
        assert_eq!(rs[0].ts, 5);
        assert_eq!(rs[1].ts, 1);
        assert_eq!(rs[2].ts, 5);
    }
}
