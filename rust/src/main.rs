//! lazyevictiond — leader entrypoint / CLI.
//!
//! Subcommands:
//!   serve     --addr 127.0.0.1:8088 --policy lazy --budget 192 ...
//!   sim-serve same, over the artifact-free sim backend (no PJRT needed)
//!   generate  one-shot generation from a prompt (smoke/debug)
//!   eval      run N reasoning samples through the engine, report accuracy
//!   suggest-w print the paper's W rule for a dataset profile
//!   info      artifact + engine-shape inventory
//!
//! Paged-KV pool flags (serve/sim-serve): --pool-blocks N enables a shared
//! block pool (0 = per-row capacity, the default), --block-size (16),
//! --pool-low / --pool-high admission watermarks in blocks (or
//! --auto-watermarks to derive them from the policy's replay-measured
//! live-set p50/p95). With a pool, prompt-prefix block sharing is on by
//! default: --prefix-entries caps the cache (64), --no-prefix-cache
//! disables sharing entirely. --host-tier-bytes N adds the host spill tier
//! (demotion/promotion; see kvtier) and --preempt-mode
//! recompute|swap|auto picks how preempted rows come back.
//!
//! Fleet flags (serve/sim-serve): --replicas N runs N engine replicas
//! behind the prefix-affinity router (--routing affinity|pressure|rr,
//! --router-seed for the deterministic tie-break); --fault-injection
//! enables the kill_replica line command for chaos tests. See
//! docs/fleet.md.
//!
//! Telemetry flags (serve/sim-serve): --metrics-addr HOST:PORT starts a
//! Prometheus-style scrape listener (`GET /metrics`, `GET /trace`),
//! --trace-out FILE streams flight-recorder lifecycle events as JSONL,
//! --trace-events N bounds the in-memory flight ring (default 4096).
//! --observe-recurrence turns on the eviction recurrence observatory
//! (per-pass decision records + promotion histograms; default off — the
//! hot path stays clean). See docs/observability.md.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{Context, Result};
use lazyeviction::bench_harness::{artifacts_dir, table::Table};
use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode, Request};
use lazyeviction::eviction::PolicyParams;
use lazyeviction::kvpool::{PoolConfig, PrefixCacheConfig};
use lazyeviction::kvtier::HostTierConfig;
use lazyeviction::runtime::{Client, Manifest};
use lazyeviction::scheduler::derive_watermarks;
use lazyeviction::sim::replay::{replay, ReplayConfig};
use lazyeviction::trace::workload::{
    dataset_profile, gen_reasoning_sample, model_profile, score_sample,
};
use lazyeviction::trace::{generator, mri};
use lazyeviction::util::cli::Args;
use lazyeviction::util::rng::Rng;

fn engine_config_from(args: &Args) -> EngineConfig {
    let mut params = PolicyParams::default();
    params.window = args.usize_or("window", 25);
    params.recent = args.usize_or("recent", params.window);
    let mut cfg = EngineConfig {
        batch: args.usize_or("batch", 1),
        cache: args.usize_or("cache", 256),
        budget: args.usize_or("budget", 192),
        policy: args.str_or("policy", "lazy"),
        params,
        alpha: args.f64_or("alpha", 5e-4) as f32,
        stop_char: '\0',
        collect_sketches: false,
        record_live: !args.bool_flag("no-record-live"),
        pool: None,
        prefix_cache: None,
        host_tier: None,
        preempt_mode: PreemptMode::Recompute,
        observe_recurrence: args.bool_flag("observe-recurrence"),
    };
    cfg.collect_sketches = cfg.policy.starts_with("rkv");
    if args.bool_flag("stop-newline") {
        cfg.stop_char = '\n';
    }
    let pool_blocks = args.usize_or("pool-blocks", 0);
    if pool_blocks > 0 {
        cfg.pool = Some(PoolConfig {
            block_size: args.usize_or("block-size", 16),
            n_blocks: pool_blocks,
            low_watermark: args.usize_or("pool-low", 4),
            high_watermark: args.usize_or("pool-high", 8),
        });
        // prompt-prefix block sharing rides on the pool; on by default
        if !args.bool_flag("no-prefix-cache") {
            cfg.prefix_cache = Some(PrefixCacheConfig {
                max_entries: args.usize_or("prefix-entries", 64),
            });
        }
        // host spill tier (demotion/promotion + swap-mode preemption)
        let tier_bytes = args.usize_or("host-tier-bytes", 0);
        if tier_bytes > 0 {
            cfg.host_tier = Some(HostTierConfig {
                max_bytes: tier_bytes,
            });
        }
        let mode = args.str_or("preempt-mode", "recompute");
        cfg.preempt_mode = match PreemptMode::parse(&mode) {
            Some(m) => m,
            None => {
                eprintln!("unknown --preempt-mode '{mode}', using recompute");
                PreemptMode::Recompute
            }
        };
    }
    cfg
}

/// `--auto-watermarks`: replace the static `--pool-low/--pool-high` values
/// with ones derived from the configured policy's replay-measured live-set
/// distribution (p50/p95 → `scheduler::derive_watermarks`). A policy whose
/// live sets collapse to ≈ B + W gets a proportionally tighter band than
/// FullKV's unbounded growth — the same pool, tuned to the policy.
fn apply_auto_watermarks(args: &Args, cfg: &mut EngineConfig) -> Result<()> {
    if !args.bool_flag("auto-watermarks") {
        return Ok(());
    }
    let Some(pool) = cfg.pool.as_mut() else {
        return Ok(());
    };
    let policy = lazyeviction::eviction::build(&cfg.policy, &cfg.params)?;
    let wp = dataset_profile(&args.str_or("dataset", "gsm8k"));
    let mp = model_profile(&args.str_or("model", "ds-llama-8b"));
    let mut samples = Vec::new();
    for seed in 0..args.u64_or("auto-watermark-samples", 8) {
        let tr = generator::generate(&wp, &mp, 1000 + seed);
        let mut rc = ReplayConfig::new(cfg.budget, cfg.params.window + 2, cfg.alpha);
        rc.record_live = true;
        samples.extend(replay(&tr, policy.as_ref(), rc).live_curve);
    }
    let (low, high) = derive_watermarks(&samples, pool.block_size, pool.n_blocks);
    eprintln!(
        "auto-watermarks: {} live-set samples for policy {} → low={low} high={high} \
         (was {}/{})",
        samples.len(),
        cfg.policy,
        pool.low_watermark,
        pool.high_watermark
    );
    pool.low_watermark = low;
    pool.high_watermark = high;
    Ok(())
}

fn build_engine(args: &Args) -> Result<Engine> {
    let dir = args.str_or("artifacts", artifacts_dir().to_string_lossy().as_ref());
    let manifest = Manifest::load(&dir).context("loading manifest (run `make artifacts`)")?;
    let client = Client::cpu()?;
    let mut cfg = engine_config_from(args);
    apply_auto_watermarks(args, &mut cfg)?;
    eprintln!(
        "engine: batch={} cache={} budget={} policy={}",
        cfg.batch, cfg.cache, cfg.budget, cfg.policy
    );
    Engine::new(&client, &manifest, cfg)
}

/// Build the optional telemetry handle from `--metrics-addr`, `--trace-out`
/// and `--trace-events`, and start the scrape listener when one is asked
/// for. `None` (no flags) keeps serving exactly as before — zero overhead.
fn telemetry_from(
    args: &Args,
    shutdown: &Arc<AtomicBool>,
) -> Result<Option<Arc<lazyeviction::telemetry::Telemetry>>> {
    use lazyeviction::telemetry::{spawn_metrics_listener, FlightRecorder, Telemetry};
    let metrics_addr = args.get("metrics-addr");
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    if metrics_addr.is_none() && trace_out.is_none() {
        return Ok(None);
    }
    let cap = args.usize_or("trace-events", FlightRecorder::DEFAULT_CAP);
    let t = Telemetry::with_trace(cap, trace_out.as_deref()).context("opening --trace-out")?;
    if let Some(addr) = metrics_addr {
        spawn_metrics_listener(addr, t.clone(), shutdown.clone())
            .with_context(|| format!("binding --metrics-addr {addr}"))?;
        eprintln!("metrics: http://{addr}/metrics");
    }
    Ok(Some(t))
}

/// Fleet flags shared by serve/sim-serve: `--replicas N` (default 1),
/// `--routing affinity|pressure|rr`, `--router-seed`, `--fault-injection`
/// (enables the `kill_replica` line command — chaos testing only).
fn fleet_options_from(args: &Args) -> Result<(usize, lazyeviction::server::FleetOptions)> {
    let replicas = args.usize_or("replicas", 1);
    anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
    let mut opts = lazyeviction::server::FleetOptions::default();
    if let Some(r) = args.get("routing") {
        opts.routing = lazyeviction::scheduler::Routing::parse(r)
            .ok_or_else(|| anyhow::anyhow!("unknown --routing '{r}' (affinity|pressure|rr)"))?;
    }
    opts.seed = args.u64_or("router-seed", opts.seed);
    opts.fault_injection = args.bool_flag("fault-injection");
    Ok((replicas, opts))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (replicas, opts) = fleet_options_from(args)?;
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        engines.push(build_engine(args)?);
    }
    let addr = args.str_or("addr", "127.0.0.1:8088");
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = telemetry_from(args, &shutdown)?;
    lazyeviction::server::serve_fleet(engines, &addr, shutdown, telemetry, opts)
}

fn cmd_sim_serve(args: &Args) -> Result<()> {
    let (replicas, opts) = fleet_options_from(args)?;
    let mut cfg = engine_config_from(args);
    apply_auto_watermarks(args, &mut cfg)?;
    eprintln!(
        "sim engine: batch={} cache={} budget={} policy={} (artifact-free backend)",
        cfg.batch, cfg.cache, cfg.budget, cfg.policy
    );
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        engines.push(Engine::new_sim(cfg.clone())?);
    }
    let addr = args.str_or("addr", "127.0.0.1:8088");
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = telemetry_from(args, &shutdown)?;
    lazyeviction::server::serve_fleet(engines, &addr, shutdown, telemetry, opts)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let mut engine = build_engine(args)?;
    let prompt = args.str_or("prompt", "#A=3;B=7;C=2;\n>");
    let max_new = args.usize_or("max-new", 64);
    let responses = engine.run_all(vec![Request {
        id: 1,
        prompt: prompt.clone(),
        template: args.str_or("template", ""),
        max_new,
        resume: None,
    }])?;
    for r in responses {
        println!("prompt : {prompt:?}");
        println!("output : {:?}", r.text);
        println!(
            "finish : {} ({} tokens, {:.1} ms total, ttft {:.1} ms, {} evictions)",
            r.finish.as_str(),
            r.metrics.tokens_out,
            r.metrics.total_s * 1e3,
            r.metrics.ttft_s * 1e3,
            r.metrics.evictions
        );
    }
    let m = &engine.metrics;
    eprintln!(
        "steps: {} decode, mean {:.2} ms, throughput {:.1} tok/s",
        m.steps,
        m.step_summary_ms().mean,
        m.throughput()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mut engine = build_engine(args)?;
    let n = args.usize_or("samples", 16);
    let n_facts = args.usize_or("facts", 4);
    let n_queries = args.usize_or("queries", 8);
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let mut samples = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..n {
        let s = gen_reasoning_sample(&mut rng, n_facts, n_queries);
        reqs.push(Request {
            id: i as u64,
            prompt: s.prompt.clone(),
            template: s.template.clone(),
            max_new: s.template.chars().count() + 4,
            resume: None,
        });
        samples.push(s);
    }
    let responses = engine.run_all(reqs)?;
    let mut total_acc = 0.0;
    for r in &responses {
        let s = &samples[r.id as usize];
        total_acc += score_sample(s, &r.hole_predictions);
    }
    let m = &engine.metrics;
    println!(
        "eval: {} samples, hole accuracy {:.1}%, throughput {:.1} tok/s, mean step {:.2} ms",
        responses.len(),
        100.0 * total_acc / responses.len().max(1) as f64,
        m.throughput(),
        m.step_summary_ms().mean
    );
    Ok(())
}

fn cmd_suggest_w(args: &Args) -> Result<()> {
    let ds = args.str_or("dataset", "gsm8k");
    let model = args.str_or("model", "ds-llama-8b");
    let n = args.usize_or("samples", 8);
    let wp = dataset_profile(&ds);
    let mp = model_profile(&model);
    let traces: Vec<_> = (0..n as u64)
        .map(|s| generator::generate(&wp, &mp, s))
        .collect();
    let w = mri::suggest_window(&traces, mp.alpha, args.f64_or("pct", 0.8));
    let frac = mri::recurrence_fraction(&traces, mp.alpha);
    println!(
        "dataset={ds} model={model}: recurrence fraction {:.1}%, suggested W = {w}",
        frac * 100.0
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", artifacts_dir().to_string_lossy().as_ref());
    let manifest = Manifest::load(&dir)?;
    println!(
        "model: vocab={} d_model={} layers={} heads={} d_head={} (charset {} chars)",
        manifest.model.vocab,
        manifest.model.d_model,
        manifest.model.n_layers,
        manifest.model.n_heads,
        manifest.model.d_head,
        manifest.charset.chars().count()
    );
    let mut t = Table::new(&["kind", "name", "batch", "cache", "prefill"]);
    for v in &manifest.variants {
        t.row(vec![
            format!("{:?}", v.kind),
            v.name.clone(),
            v.batch.to_string(),
            v.cache.to_string(),
            v.prefill.to_string(),
        ]);
    }
    t.print();
    println!("engine shapes: {:?}", manifest.engine_shapes());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("sim-serve") => cmd_sim_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("eval") => cmd_eval(&args),
        Some("suggest-w") => cmd_suggest_w(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: lazyevictiond <serve|sim-serve|generate|eval|suggest-w|info> [--flags]\n\
                 common flags: --artifacts DIR --policy P --budget B --cache S --batch N --window W\n\
                 pool flags:   --pool-blocks N --block-size 16 --pool-low 4 --pool-high 8 --auto-watermarks\n\
                 prefix flags: --prefix-entries 64 --no-prefix-cache\n\
                 tier flags:   --host-tier-bytes N --preempt-mode recompute|swap|auto\n\
                 fleet flags:  --replicas N --routing affinity|pressure|rr --router-seed S --fault-injection\n\
                 telemetry:    --metrics-addr HOST:PORT --trace-out FILE --trace-events 4096 --observe-recurrence\n\
                 every flag and the server's pool gauge fields: docs/serving.md; fleet: docs/fleet.md"
            );
            std::process::exit(2);
        }
    }
}
