//! ModelExecutor: device-resident KV serving of one engine shape (B, S).
//!
//! The KV cache K/V live as PJRT device buffers for the whole generation;
//! `step` feeds them (plus the once-uploaded weights) by reference via
//! `execute_b`, and the cache-maintenance executables (`append`, `gather`,
//! `insert`) are single-output so their results chain device-side without a
//! host round-trip. Only small tensors cross the host boundary each step:
//! slot_mask/token/pos up; logits + aggregated attention + per-layer new K/V
//! rows down. This is the L3 hot path.

use anyhow::{Context, Result};

use super::backend::DecodeBackend;
use super::client::Client;
use super::manifest::{Manifest, Variant, VariantKind};

/// Host-side copy of one decode step's outputs.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// [B * V] row-major.
    pub logits: Vec<f32>,
    /// [B * S] aggregated slot attention (mean over layers of max over heads)
    /// — or [L * H * S] per-layer/head for the trace variant (B = 1).
    pub attn: Vec<f32>,
    /// [B * L * H * dh] current token keys (RoPE applied).
    pub k_new: Vec<f32>,
    /// [B * L * H * dh] current token values.
    pub v_new: Vec<f32>,
}

/// Host-side copy of a prefill's outputs (batch-1 executable).
#[derive(Debug)]
pub struct PrefillOut {
    /// [L * H * S * dh] — ready for `insert`.
    pub k_seq: Vec<f32>,
    pub v_seq: Vec<f32>,
    /// [P] last-valid-row aggregated attention over prompt tokens.
    pub attn_last: Vec<f32>,
    /// [V] logits at the last valid position.
    pub logits_last: Vec<f32>,
}

pub struct ModelExecutor {
    pub batch: usize,
    pub cache: usize,
    pub prefill_bucket: usize,
    dims: super::manifest::ModelDims,

    client: xla::PjRtClient,
    step_exe: xla::PjRtLoadedExecutable,
    append_exe: xla::PjRtLoadedExecutable,
    gather_exe: xla::PjRtLoadedExecutable,
    insert_exe: xla::PjRtLoadedExecutable,
    prefill_exe: xla::PjRtLoadedExecutable,

    weights: Vec<xla::PjRtBuffer>,
    k_cache: xla::PjRtBuffer,
    v_cache: xla::PjRtBuffer,

    /// Cumulative count of PJRT executions, by kind (perf accounting).
    pub exec_counts: ExecCounts,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ExecCounts {
    pub step: u64,
    pub append: u64,
    pub gather: u64,
    pub insert: u64,
    pub prefill: u64,
}

fn take_single(out: Vec<Vec<xla::PjRtBuffer>>) -> Result<xla::PjRtBuffer> {
    let replica = out
        .into_iter()
        .next()
        .context("executable returned no replicas")?;
    anyhow::ensure!(replica.len() == 1, "expected single-output executable");
    Ok(replica.into_iter().next().unwrap())
}

impl ModelExecutor {
    /// Compile + load everything for engine shape (batch, cache).
    pub fn new(client: &Client, manifest: &Manifest, batch: usize, cache: usize) -> Result<Self> {
        Self::new_inner(client, manifest, batch, cache, false)
    }

    /// Trace-mode executor: the step executable is the `trace` variant whose
    /// attention output is per-layer/per-head [L,H,S] (batch 1) — used by the
    /// Fig. 2/3 analyses on the real model.
    pub fn new_trace(client: &Client, manifest: &Manifest, cache: usize) -> Result<Self> {
        Self::new_inner(client, manifest, 1, cache, true)
    }

    fn new_inner(
        client: &Client,
        manifest: &Manifest,
        batch: usize,
        cache: usize,
        trace_mode: bool,
    ) -> Result<Self> {
        let get = |kind: VariantKind, b: usize| -> Result<&Variant> {
            manifest.find(kind.clone(), b, cache).ok_or_else(|| {
                anyhow::anyhow!("manifest has no {kind:?} variant for b{b} s{cache}")
            })
        };
        let compile = |v: &Variant| client.compile_file(manifest.dir.join(&v.file));

        // LAZYEVICTION_FUSED=1 selects the XLA-fused-attention step variant
        // (2.5x faster under CPU PJRT; Pallas remains the default/verified
        // path). Falls back to the Pallas step when the variant is absent.
        let fused = std::env::var("LAZYEVICTION_FUSED").map(|v| v == "1").unwrap_or(false);
        let step_kind = if trace_mode {
            VariantKind::Trace
        } else if fused && manifest.find(VariantKind::StepFused, batch, cache).is_some() {
            VariantKind::StepFused
        } else {
            VariantKind::Step
        };
        let step_v = get(step_kind, batch)?;
        let append_v = get(VariantKind::Append, batch)?;
        let gather_v = get(VariantKind::Gather, batch)?;
        let insert_v = get(VariantKind::Insert, batch)?;
        let prefill_v = manifest
            .variants
            .iter()
            .find(|v| v.kind == VariantKind::Prefill && v.cache == cache)
            .context("no prefill variant for this cache size")?;

        let dims = manifest.model.clone();
        let weights_flat = manifest.load_weights()?;
        let mut weights = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let data = &weights_flat[p.offset_f32..p.offset_f32 + p.size_f32];
            weights.push(client.upload_f32(data, &p.shape)?);
        }

        let (l, h, dh) = (dims.n_layers, dims.n_heads, dims.d_head);
        let cache_len = batch * l * h * cache * dh;
        let cache_dims = [batch, l, h, cache, dh];
        let zeros = vec![0f32; cache_len];
        let k_cache = client.upload_f32(&zeros, &cache_dims)?;
        let v_cache = client.upload_f32(&zeros, &cache_dims)?;

        Ok(ModelExecutor {
            batch,
            cache,
            prefill_bucket: prefill_v.prefill,
            dims,
            client: client.raw().clone(),
            step_exe: compile(step_v)?,
            append_exe: compile(append_v)?,
            gather_exe: compile(gather_v)?,
            insert_exe: compile(insert_v)?,
            prefill_exe: compile(prefill_v)?,
            weights,
            k_cache,
            v_cache,
            exec_counts: ExecCounts::default(),
        })
    }

    pub fn dims(&self) -> &super::manifest::ModelDims {
        &self.dims
    }

    /// KV bytes held on device for this engine (both caches).
    pub fn device_cache_bytes(&self) -> usize {
        2 * self.batch
            * self.dims.n_layers
            * self.dims.n_heads
            * self.cache
            * self.dims.d_head
            * 4
    }

    /// Run one decode step. `slot_mask` is [B*S] (1.0 = live slot),
    /// `tokens`/`pos` are per-batch-row current token and absolute position.
    pub fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(slot_mask.len() == b * s && tokens.len() == b && pos.len() == b);
        // kImmutableOnlyDuringCall semantics: synchronous copies (see client.rs)
        let mask_buf = self.client.buffer_from_host_buffer(slot_mask, &[b, s], None)?;
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[b], None)?;
        let pos_buf = self.client.buffer_from_host_buffer(pos, &[b], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&self.k_cache);
        args.push(&self.v_cache);
        args.push(&mask_buf);
        args.push(&tok_buf);
        args.push(&pos_buf);

        let out = self.step_exe.execute_b(&args)?;
        self.exec_counts.step += 1;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "step: expected 4 outputs");
        Ok(StepOut {
            logits: parts[0].to_vec::<f32>()?,
            attn: parts[1].to_vec::<f32>()?,
            k_new: parts[2].to_vec::<f32>()?,
            v_new: parts[3].to_vec::<f32>()?,
        })
    }

    /// Append this step's K/V rows at per-row slot indices (device-side DUS).
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], idx: &[i32]) -> Result<()> {
        let (b, l, h, dh) = (
            self.batch,
            self.dims.n_layers,
            self.dims.n_heads,
            self.dims.d_head,
        );
        anyhow::ensure!(idx.len() == b && k_new.len() == b * l * h * dh);
        let new_dims = [b, l, h, dh];
        let idx_buf = self.client.buffer_from_host_buffer(idx, &[b], None)?;

        let kn = self.client.buffer_from_host_buffer(k_new, &new_dims, None)?;
        let out = self.append_exe.execute_b(&[&self.k_cache, &kn, &idx_buf])?;
        self.k_cache = take_single(out)?;

        let vn = self.client.buffer_from_host_buffer(v_new, &new_dims, None)?;
        let out = self.append_exe.execute_b(&[&self.v_cache, &vn, &idx_buf])?;
        self.v_cache = take_single(out)?;
        self.exec_counts.append += 2;
        Ok(())
    }

    /// Compact/permute slots of both caches: new[b][j] = old[b][idx[b*S+j]].
    pub fn gather(&mut self, idx: &[i32]) -> Result<()> {
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(idx.len() == b * s);
        let idx_buf = self.client.buffer_from_host_buffer(idx, &[b, s], None)?;
        let out = self.gather_exe.execute_b(&[&self.k_cache, &idx_buf])?;
        self.k_cache = take_single(out)?;
        let out = self.gather_exe.execute_b(&[&self.v_cache, &idx_buf])?;
        self.v_cache = take_single(out)?;
        self.exec_counts.gather += 2;
        Ok(())
    }

    /// Run the batch-1 prefill executable over a padded prompt bucket.
    pub fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut> {
        let p = self.prefill_bucket;
        anyhow::ensure!(tokens.len() == p && valid.len() == p);
        let tok = self.client.buffer_from_host_buffer(tokens, &[1, p], None)?;
        let val = self.client.buffer_from_host_buffer(valid, &[1, p], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&val);
        let out = self.prefill_exe.execute_b(&args)?;
        self.exec_counts.prefill += 1;
        let parts = out[0][0].to_literal_sync()?.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "prefill: expected 4 outputs");
        Ok(PrefillOut {
            k_seq: parts[0].to_vec::<f32>()?,
            v_seq: parts[1].to_vec::<f32>()?,
            attn_last: parts[2].to_vec::<f32>()?,
            logits_last: parts[3].to_vec::<f32>()?,
        })
    }

    /// Insert a prefilled sequence cache ([L,H,S,dh] host data) at batch row b.
    pub fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()> {
        let (l, h, s, dh) = (
            self.dims.n_layers,
            self.dims.n_heads,
            self.cache,
            self.dims.d_head,
        );
        anyhow::ensure!(k_seq.len() == l * h * s * dh && row < self.batch);
        let seq_dims = [l, h, s, dh];
        let row_buf = self.client.buffer_from_host_buffer(&[row as i32], &[], None)?;

        let ks = self.client.buffer_from_host_buffer(k_seq, &seq_dims, None)?;
        let out = self.insert_exe.execute_b(&[&self.k_cache, &ks, &row_buf])?;
        self.k_cache = take_single(out)?;

        let vs = self.client.buffer_from_host_buffer(v_seq, &seq_dims, None)?;
        let out = self.insert_exe.execute_b(&[&self.v_cache, &vs, &row_buf])?;
        self.v_cache = take_single(out)?;
        self.exec_counts.insert += 2;
        Ok(())
    }

    /// Download both caches to host (test/debug only — not on the hot path).
    pub fn download_caches(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        Ok((
            self.k_cache.to_literal_sync()?.to_vec::<f32>()?,
            self.v_cache.to_literal_sync()?.to_vec::<f32>()?,
        ))
    }
}

/// The PJRT executor is the real-model [`DecodeBackend`]; the coordinator
/// drives it through this trait so the same decode loop also runs over the
/// artifact-free sim backend.
impl DecodeBackend for ModelExecutor {
    fn dims(&self) -> &super::manifest::ModelDims {
        &self.dims
    }

    fn prefill_bucket(&self) -> usize {
        self.prefill_bucket
    }

    fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut> {
        ModelExecutor::prefill(self, tokens, valid)
    }

    fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()> {
        ModelExecutor::insert(self, k_seq, v_seq, row)
    }

    fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        ModelExecutor::step(self, slot_mask, tokens, pos)
    }

    fn append(&mut self, k_new: &[f32], v_new: &[f32], idx: &[i32]) -> Result<()> {
        ModelExecutor::append(self, k_new, v_new, idx)
    }

    fn gather(&mut self, idx: &[i32]) -> Result<()> {
        ModelExecutor::gather(self, idx)
    }

    fn exec_counts(&self) -> ExecCounts {
        self.exec_counts
    }

    fn device_cache_bytes(&self) -> usize {
        ModelExecutor::device_cache_bytes(self)
    }
}
