//! ModelExecutor: device-resident KV serving of one engine shape (B, S).
//!
//! The KV cache K/V live as PJRT device buffers for the whole generation;
//! `step` feeds them (plus the once-uploaded weights) by reference via
//! `execute_b`, and the cache-maintenance executables (`append`, `gather`,
//! `insert`) are single-output so their results chain device-side without a
//! host round-trip. Only small tensors cross the host boundary each step:
//! slot_mask/token/pos up; logits + aggregated attention + per-layer new K/V
//! rows down. This is the L3 hot path.
//!
//! ## Paged mode
//!
//! With a block pool, `init_paged` swaps the dense per-row `[B, L, H, S,
//! dh]` caches (which are then never allocated — allocation is lazy, on
//! first dense use) for pool-shaped `[n_blocks, block_size, L, H, dh]`
//! arena buffers plus three extra executables from the manifest:
//! `stepp` (decode step reading K/V through `[B, max_blocks]` block tables
//! + `[B]` lens — the Pallas/XLA paged-attention path), `blockw` (write one
//! `[L, H, dh]` row at a linear arena slot), and `blockg` (permute all
//! arena rows by a linear index vector — serving both CoW block copies and
//! eviction compaction in a single device pass, with gather's functional
//! output giving the required two-phase semantics for free). Artifacts are
//! emitted per arena geometry by `python/compile/aot.py`; a manifest
//! predating paged variants makes `init_paged` fail with a regenerate hint
//! rather than silently falling back to worst-case buffers.

use anyhow::{Context, Result};

use super::backend::{DecodeBackend, PrefillRows};
use super::client::Client;
use super::manifest::{Manifest, Variant, VariantKind};
use crate::kvpool::{BlockCopy, BlockId, RowMove};

/// Host-side copy of one decode step's outputs.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// [B * V] row-major.
    pub logits: Vec<f32>,
    /// [B * S] aggregated slot attention (mean over layers of max over heads)
    /// — or [L * H * S] per-layer/head for the trace variant (B = 1).
    pub attn: Vec<f32>,
    /// [B * L * H * dh] current token keys (RoPE applied).
    pub k_new: Vec<f32>,
    /// [B * L * H * dh] current token values.
    pub v_new: Vec<f32>,
}

/// Host-side copy of a prefill's outputs (batch-1 executable).
#[derive(Debug)]
pub struct PrefillOut {
    /// [L * H * S * dh] — ready for `insert`.
    pub k_seq: Vec<f32>,
    pub v_seq: Vec<f32>,
    /// [P] last-valid-row aggregated attention over prompt tokens.
    pub attn_last: Vec<f32>,
    /// [V] logits at the last valid position.
    pub logits_last: Vec<f32>,
}

/// Device-side paged-KV state: block arenas + the executables that serve
/// them (see module docs §Paged mode).
struct PagedExec {
    n_blocks: usize,
    block_size: usize,
    step_exe: xla::PjRtLoadedExecutable,
    write_exe: xla::PjRtLoadedExecutable,
    gather_exe: xla::PjRtLoadedExecutable,
    k_arena: xla::PjRtBuffer,
    v_arena: xla::PjRtBuffer,
}

pub struct ModelExecutor {
    pub batch: usize,
    pub cache: usize,
    pub prefill_bucket: usize,
    dims: super::manifest::ModelDims,
    /// Kept for paged-executable compilation at `init_paged` time.
    manifest: Manifest,

    client: xla::PjRtClient,
    step_exe: xla::PjRtLoadedExecutable,
    append_exe: xla::PjRtLoadedExecutable,
    gather_exe: xla::PjRtLoadedExecutable,
    insert_exe: xla::PjRtLoadedExecutable,
    prefill_exe: xla::PjRtLoadedExecutable,

    weights: Vec<xla::PjRtBuffer>,
    /// Dense per-row caches — allocated lazily on first dense-layout use, so
    /// a paged engine never holds the worst-case `[B, L, H, S, dh]` buffers.
    k_cache: Option<xla::PjRtBuffer>,
    v_cache: Option<xla::PjRtBuffer>,
    paged: Option<PagedExec>,

    /// Cumulative count of PJRT executions, by kind (perf accounting).
    pub exec_counts: ExecCounts,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ExecCounts {
    pub step: u64,
    pub append: u64,
    pub gather: u64,
    pub insert: u64,
    pub prefill: u64,
    /// Paged mode: K/V rows written into arena blocks.
    pub row_writes: u64,
    /// Paged mode: copy-on-write block duplications.
    pub block_copies: u64,
    /// Paged mode: rows relocated by eviction compaction.
    pub row_moves: u64,
    /// Host tier: block payloads copied device→host (demotion / swap-out).
    pub block_swap_outs: u64,
    /// Host tier: block payloads copied host→device (promotion / swap-in).
    pub block_swap_ins: u64,
}

fn take_single(out: Vec<Vec<xla::PjRtBuffer>>) -> Result<xla::PjRtBuffer> {
    let replica = out
        .into_iter()
        .next()
        .context("executable returned no replicas")?;
    anyhow::ensure!(replica.len() == 1, "expected single-output executable");
    Ok(replica.into_iter().next().unwrap())
}

impl ModelExecutor {
    /// Compile + load everything for engine shape (batch, cache).
    pub fn new(client: &Client, manifest: &Manifest, batch: usize, cache: usize) -> Result<Self> {
        Self::new_inner(client, manifest, batch, cache, false)
    }

    /// Trace-mode executor: the step executable is the `trace` variant whose
    /// attention output is per-layer/per-head [L,H,S] (batch 1) — used by the
    /// Fig. 2/3 analyses on the real model.
    pub fn new_trace(client: &Client, manifest: &Manifest, cache: usize) -> Result<Self> {
        Self::new_inner(client, manifest, 1, cache, true)
    }

    fn new_inner(
        client: &Client,
        manifest: &Manifest,
        batch: usize,
        cache: usize,
        trace_mode: bool,
    ) -> Result<Self> {
        let get = |kind: VariantKind, b: usize| -> Result<&Variant> {
            manifest.find(kind.clone(), b, cache).ok_or_else(|| {
                anyhow::anyhow!("manifest has no {kind:?} variant for b{b} s{cache}")
            })
        };
        let compile = |v: &Variant| client.compile_file(manifest.dir.join(&v.file));

        // LAZYEVICTION_FUSED=1 selects the XLA-fused-attention step variant
        // (2.5x faster under CPU PJRT; Pallas remains the default/verified
        // path). Falls back to the Pallas step when the variant is absent.
        let fused = std::env::var("LAZYEVICTION_FUSED").map(|v| v == "1").unwrap_or(false);
        let step_kind = if trace_mode {
            VariantKind::Trace
        } else if fused && manifest.find(VariantKind::StepFused, batch, cache).is_some() {
            VariantKind::StepFused
        } else {
            VariantKind::Step
        };
        let step_v = get(step_kind, batch)?;
        let append_v = get(VariantKind::Append, batch)?;
        let gather_v = get(VariantKind::Gather, batch)?;
        let insert_v = get(VariantKind::Insert, batch)?;
        let prefill_v = manifest
            .variants
            .iter()
            .find(|v| v.kind == VariantKind::Prefill && v.cache == cache)
            .context("no prefill variant for this cache size")?;

        let dims = manifest.model.clone();
        let weights_flat = manifest.load_weights()?;
        let mut weights = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let data = &weights_flat[p.offset_f32..p.offset_f32 + p.size_f32];
            weights.push(client.upload_f32(data, &p.shape)?);
        }

        Ok(ModelExecutor {
            batch,
            cache,
            prefill_bucket: prefill_v.prefill,
            dims,
            manifest: manifest.clone(),
            client: client.raw().clone(),
            step_exe: compile(step_v)?,
            append_exe: compile(append_v)?,
            gather_exe: compile(gather_v)?,
            insert_exe: compile(insert_v)?,
            prefill_exe: compile(prefill_v)?,
            weights,
            k_cache: None,
            v_cache: None,
            paged: None,
            exec_counts: ExecCounts::default(),
        })
    }

    /// Allocate the dense per-row caches on first dense-layout use (never in
    /// paged mode — the arenas are the only physical KV there).
    fn ensure_dense_caches(&mut self) -> Result<()> {
        anyhow::ensure!(self.paged.is_none(), "dense cache op on a paged executor");
        if self.k_cache.is_some() {
            return Ok(());
        }
        let (l, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        let cache_dims = [self.batch, l, h, self.cache, dh];
        let zeros = vec![0f32; self.batch * l * h * self.cache * dh];
        self.k_cache = Some(
            self.client
                .buffer_from_host_buffer(&zeros, &cache_dims, None)?,
        );
        self.v_cache = Some(
            self.client
                .buffer_from_host_buffer(&zeros, &cache_dims, None)?,
        );
        Ok(())
    }

    fn compile_artifact(&self, v: &Variant) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.dir.join(&v.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        self.client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn dims(&self) -> &super::manifest::ModelDims {
        &self.dims
    }

    fn row_elems(&self) -> usize {
        self.dims.n_layers * self.dims.n_heads * self.dims.d_head
    }

    /// KV bytes held on device for this engine: the block arenas in paged
    /// mode, the dense caches once allocated, zero before first use.
    pub fn device_cache_bytes(&self) -> usize {
        if let Some(p) = &self.paged {
            2 * p.n_blocks * p.block_size * self.row_elems() * 4
        } else if self.k_cache.is_some() {
            2 * self.batch * self.cache * self.row_elems() * 4
        } else {
            0
        }
    }

    /// Run one decode step. `slot_mask` is [B*S] (1.0 = live slot),
    /// `tokens`/`pos` are per-batch-row current token and absolute position.
    pub fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(slot_mask.len() == b * s && tokens.len() == b && pos.len() == b);
        self.ensure_dense_caches()?;
        // kImmutableOnlyDuringCall semantics: synchronous copies (see client.rs)
        let mask_buf = self.client.buffer_from_host_buffer(slot_mask, &[b, s], None)?;
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[b], None)?;
        let pos_buf = self.client.buffer_from_host_buffer(pos, &[b], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(self.k_cache.as_ref().expect("ensured"));
        args.push(self.v_cache.as_ref().expect("ensured"));
        args.push(&mask_buf);
        args.push(&tok_buf);
        args.push(&pos_buf);

        let out = self.step_exe.execute_b(&args)?;
        self.exec_counts.step += 1;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "step: expected 4 outputs");
        Ok(StepOut {
            logits: parts[0].to_vec::<f32>()?,
            attn: parts[1].to_vec::<f32>()?,
            k_new: parts[2].to_vec::<f32>()?,
            v_new: parts[3].to_vec::<f32>()?,
        })
    }

    /// Append this step's K/V rows at per-row slot indices (device-side DUS).
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], idx: &[i32]) -> Result<()> {
        let (b, l, h, dh) = (
            self.batch,
            self.dims.n_layers,
            self.dims.n_heads,
            self.dims.d_head,
        );
        anyhow::ensure!(idx.len() == b && k_new.len() == b * l * h * dh);
        self.ensure_dense_caches()?;
        let new_dims = [b, l, h, dh];
        let idx_buf = self.client.buffer_from_host_buffer(idx, &[b], None)?;

        let kn = self.client.buffer_from_host_buffer(k_new, &new_dims, None)?;
        let out = self
            .append_exe
            .execute_b(&[self.k_cache.as_ref().expect("ensured"), &kn, &idx_buf])?;
        self.k_cache = Some(take_single(out)?);

        let vn = self.client.buffer_from_host_buffer(v_new, &new_dims, None)?;
        let out = self
            .append_exe
            .execute_b(&[self.v_cache.as_ref().expect("ensured"), &vn, &idx_buf])?;
        self.v_cache = Some(take_single(out)?);
        self.exec_counts.append += 2;
        Ok(())
    }

    /// Compact/permute slots of both caches: new[b][j] = old[b][idx[b*S+j]].
    pub fn gather(&mut self, idx: &[i32]) -> Result<()> {
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(idx.len() == b * s);
        self.ensure_dense_caches()?;
        let idx_buf = self.client.buffer_from_host_buffer(idx, &[b, s], None)?;
        let out = self
            .gather_exe
            .execute_b(&[self.k_cache.as_ref().expect("ensured"), &idx_buf])?;
        self.k_cache = Some(take_single(out)?);
        let out = self
            .gather_exe
            .execute_b(&[self.v_cache.as_ref().expect("ensured"), &idx_buf])?;
        self.v_cache = Some(take_single(out)?);
        self.exec_counts.gather += 2;
        Ok(())
    }

    /// Run the batch-1 prefill executable over a padded prompt bucket.
    pub fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut> {
        let p = self.prefill_bucket;
        anyhow::ensure!(tokens.len() == p && valid.len() == p);
        let tok = self.client.buffer_from_host_buffer(tokens, &[1, p], None)?;
        let val = self.client.buffer_from_host_buffer(valid, &[1, p], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&val);
        let out = self.prefill_exe.execute_b(&args)?;
        self.exec_counts.prefill += 1;
        let parts = out[0][0].to_literal_sync()?.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "prefill: expected 4 outputs");
        Ok(PrefillOut {
            k_seq: parts[0].to_vec::<f32>()?,
            v_seq: parts[1].to_vec::<f32>()?,
            attn_last: parts[2].to_vec::<f32>()?,
            logits_last: parts[3].to_vec::<f32>()?,
        })
    }

    /// Insert a prefilled sequence cache ([L,H,S,dh] host data) at batch row b.
    pub fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()> {
        let (l, h, s, dh) = (
            self.dims.n_layers,
            self.dims.n_heads,
            self.cache,
            self.dims.d_head,
        );
        anyhow::ensure!(k_seq.len() == l * h * s * dh && row < self.batch);
        self.ensure_dense_caches()?;
        let seq_dims = [l, h, s, dh];
        let row_buf = self.client.buffer_from_host_buffer(&[row as i32], &[], None)?;

        let ks = self.client.buffer_from_host_buffer(k_seq, &seq_dims, None)?;
        let out = self
            .insert_exe
            .execute_b(&[self.k_cache.as_ref().expect("ensured"), &ks, &row_buf])?;
        self.k_cache = Some(take_single(out)?);

        let vs = self.client.buffer_from_host_buffer(v_seq, &seq_dims, None)?;
        let out = self
            .insert_exe
            .execute_b(&[self.v_cache.as_ref().expect("ensured"), &vs, &row_buf])?;
        self.v_cache = Some(take_single(out)?);
        self.exec_counts.insert += 2;
        Ok(())
    }

    /// Download both caches to host (test/debug only — not on the hot path).
    pub fn download_caches(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let (k, v) = match (&self.k_cache, &self.v_cache) {
            (Some(k), Some(v)) => (k, v),
            _ => anyhow::bail!("dense caches not allocated (paged mode or unused)"),
        };
        Ok((
            k.to_literal_sync()?.to_vec::<f32>()?,
            v.to_literal_sync()?.to_vec::<f32>()?,
        ))
    }

    /// Permute both arena buffers by a full linear row index (out[j] =
    /// in[idx[j]]) — the single device pass behind CoW copies and
    /// compaction moves.
    fn arena_permute(&mut self, idx: &[i32]) -> Result<()> {
        let p = self.paged.as_mut().expect("paged");
        let idx_buf = self
            .client
            .buffer_from_host_buffer(idx, &[idx.len()], None)?;
        let out = p.gather_exe.execute_b(&[&p.k_arena, &idx_buf])?;
        p.k_arena = take_single(out)?;
        let out = p.gather_exe.execute_b(&[&p.v_arena, &idx_buf])?;
        p.v_arena = take_single(out)?;
        Ok(())
    }
}

/// The PJRT executor is the real-model [`DecodeBackend`]; the coordinator
/// drives it through this trait so the same decode loop also runs over the
/// artifact-free sim backend.
impl DecodeBackend for ModelExecutor {
    fn dims(&self) -> &super::manifest::ModelDims {
        &self.dims
    }

    fn prefill_bucket(&self) -> usize {
        self.prefill_bucket
    }

    fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut> {
        ModelExecutor::prefill(self, tokens, valid)
    }

    fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()> {
        ModelExecutor::insert(self, k_seq, v_seq, row)
    }

    fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        ModelExecutor::step(self, slot_mask, tokens, pos)
    }

    fn append(&mut self, k_new: &[f32], v_new: &[f32], idx: &[i32]) -> Result<()> {
        ModelExecutor::append(self, k_new, v_new, idx)
    }

    fn gather(&mut self, idx: &[i32]) -> Result<()> {
        ModelExecutor::gather(self, idx)
    }

    fn exec_counts(&self) -> ExecCounts {
        self.exec_counts
    }

    fn device_cache_bytes(&self) -> usize {
        ModelExecutor::device_cache_bytes(self)
    }

    fn init_paged(&mut self, n_blocks: usize, block_size: usize) -> Result<()> {
        anyhow::ensure!(self.paged.is_none(), "init_paged called twice");
        anyhow::ensure!(
            self.k_cache.is_none(),
            "init_paged after dense caches were allocated"
        );
        let find = |kind: VariantKind, batch: usize| -> Result<Variant> {
            self.manifest
                .find_paged(kind.clone(), batch, n_blocks, block_size)
                .cloned()
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "manifest has no {kind:?} paged variant for b{batch} \
                         {n_blocks}x{block_size} — regenerate artifacts \
                         (python -m compile.aot emits stepp/blockw/blockg)"
                    )
                })
        };
        let step_v = find(VariantKind::StepPaged, self.batch)?;
        let write_v = find(VariantKind::BlockWrite, 0)?;
        let gather_v = find(VariantKind::BlockGather, 0)?;
        let step_exe = self.compile_artifact(&step_v)?;
        let write_exe = self.compile_artifact(&write_v)?;
        let gather_exe = self.compile_artifact(&gather_v)?;
        let (l, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        let arena_dims = [n_blocks, block_size, l, h, dh];
        let zeros = vec![0f32; n_blocks * block_size * l * h * dh];
        let k_arena = self.client.buffer_from_host_buffer(&zeros, &arena_dims, None)?;
        let v_arena = self.client.buffer_from_host_buffer(&zeros, &arena_dims, None)?;
        self.paged = Some(PagedExec {
            n_blocks,
            block_size,
            step_exe,
            write_exe,
            gather_exe,
            k_arena,
            v_arena,
        });
        Ok(())
    }

    fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    fn prefill_rows(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillRows> {
        // same executable as the dense path; only the host-side layout of
        // the returned K/V differs (token-major rows, valid prefix only)
        let out = ModelExecutor::prefill(self, tokens, valid)?;
        let n = valid.iter().filter(|&&v| v > 0.0).count().max(1);
        let (l, h, dh, s) = (
            self.dims.n_layers,
            self.dims.n_heads,
            self.dims.d_head,
            self.cache,
        );
        let re = self.row_elems();
        let mut k_rows = vec![0f32; n * re];
        let mut v_rows = vec![0f32; n * re];
        for i in 0..n {
            for li in 0..l {
                for hi in 0..h {
                    let src = ((li * h + hi) * s + i) * dh;
                    let dst = i * re + (li * h + hi) * dh;
                    k_rows[dst..dst + dh].copy_from_slice(&out.k_seq[src..src + dh]);
                    v_rows[dst..dst + dh].copy_from_slice(&out.v_seq[src..src + dh]);
                }
            }
        }
        Ok(PrefillRows {
            k_rows,
            v_rows,
            attn_last: out.attn_last[..n].to_vec(),
            logits_last: out.logits_last,
        })
    }

    fn write_kv_rows(
        &mut self,
        block: BlockId,
        offset: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()> {
        let re = self.row_elems();
        let (l, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        let n = k_rows.len() / re;
        anyhow::ensure!(k_rows.len() == n * re && v_rows.len() == k_rows.len());
        let p = self.paged.as_mut().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        anyhow::ensure!(offset + n <= p.block_size, "write crosses block boundary");
        for i in 0..n {
            let slot = (block as usize * p.block_size + offset + i) as i32;
            let slot_buf = self.client.buffer_from_host_buffer(&[slot], &[], None)?;
            let kr = self.client.buffer_from_host_buffer(
                &k_rows[i * re..(i + 1) * re],
                &[l, h, dh],
                None,
            )?;
            let out = p.write_exe.execute_b(&[&p.k_arena, &kr, &slot_buf])?;
            p.k_arena = take_single(out)?;
            let vr = self.client.buffer_from_host_buffer(
                &v_rows[i * re..(i + 1) * re],
                &[l, h, dh],
                None,
            )?;
            let out = p.write_exe.execute_b(&[&p.v_arena, &vr, &slot_buf])?;
            p.v_arena = take_single(out)?;
        }
        self.exec_counts.row_writes += n as u64;
        Ok(())
    }

    fn copy_block(&mut self, copy: BlockCopy) -> Result<()> {
        let p = self.paged.as_ref().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        let (bs, total) = (p.block_size, p.n_blocks * p.block_size);
        anyhow::ensure!(copy.rows <= bs, "copy rows exceed block");
        let mut idx: Vec<i32> = (0..total as i32).collect();
        for r in 0..copy.rows {
            idx[copy.dst as usize * bs + r] = (copy.src as usize * bs + r) as i32;
        }
        self.arena_permute(&idx)?;
        self.exec_counts.block_copies += 1;
        Ok(())
    }

    fn gather_kv_rows(&mut self, moves: &[RowMove]) -> Result<()> {
        let p = self.paged.as_ref().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        let (bs, total) = (p.block_size, p.n_blocks * p.block_size);
        // gather is functional (reads the whole input buffer, then produces
        // a new one), so arbitrary src/dst overlap is safe in one pass
        let mut idx: Vec<i32> = (0..total as i32).collect();
        for m in moves {
            idx[m.dst_block as usize * bs + m.dst_off] =
                (m.src_block as usize * bs + m.src_off) as i32;
        }
        self.arena_permute(&idx)?;
        self.exec_counts.row_moves += moves.len() as u64;
        Ok(())
    }

    fn swap_out_block(&mut self, block: BlockId, rows: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        // Device→host copy through the existing arena buffers. PJRT's
        // literal download is whole-buffer, so this reads both arenas and
        // slices out the block's rows; swap traffic is off the decode hot
        // path (preemption/eviction time), and a dedicated block-slice
        // executable can replace this without touching the trait.
        let re = self.row_elems();
        let p = self.paged.as_ref().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        anyhow::ensure!(rows <= p.block_size, "swap-out rows exceed block");
        anyhow::ensure!((block as usize) < p.n_blocks, "swap-out block out of range");
        let k_all = p.k_arena.to_literal_sync()?.to_vec::<f32>()?;
        let v_all = p.v_arena.to_literal_sync()?.to_vec::<f32>()?;
        let a = block as usize * p.block_size * re;
        let b = a + rows * re;
        self.exec_counts.block_swap_outs += 1;
        Ok((k_all[a..b].to_vec(), v_all[a..b].to_vec()))
    }

    fn swap_in_block(&mut self, block: BlockId, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        // Host→device copy: the per-row arena write executable already does
        // exactly this, one row at a time, starting at offset 0.
        DecodeBackend::write_kv_rows(self, block, 0, k_rows, v_rows)?;
        self.exec_counts.block_swap_ins += 1;
        Ok(())
    }

    fn step_paged(
        &mut self,
        block_tables: &[i32],
        blocks_per_row: usize,
        seq_lens: &[i32],
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<StepOut> {
        let b = self.batch;
        anyhow::ensure!(
            block_tables.len() == b * blocks_per_row
                && seq_lens.len() == b
                && tokens.len() == b
                && pos.len() == b
        );
        anyhow::ensure!(self.paged.is_some(), "step_paged before init_paged");
        let tbl_buf = self
            .client
            .buffer_from_host_buffer(block_tables, &[b, blocks_per_row], None)?;
        let len_buf = self.client.buffer_from_host_buffer(seq_lens, &[b], None)?;
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[b], None)?;
        let pos_buf = self.client.buffer_from_host_buffer(pos, &[b], None)?;
        let p = self.paged.as_ref().expect("checked");
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&p.k_arena);
        args.push(&p.v_arena);
        args.push(&tbl_buf);
        args.push(&len_buf);
        args.push(&tok_buf);
        args.push(&pos_buf);
        let out = p.step_exe.execute_b(&args)?;
        self.exec_counts.step += 1;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "stepp: expected 4 outputs");
        Ok(StepOut {
            logits: parts[0].to_vec::<f32>()?,
            attn: parts[1].to_vec::<f32>()?,
            k_new: parts[2].to_vec::<f32>()?,
            v_new: parts[3].to_vec::<f32>()?,
        })
    }
}
