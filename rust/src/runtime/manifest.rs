//! Parse `artifacts/manifest.json` — the contract between the Python compile
//! path and this runtime (charset, model dims, parameter layout, executable
//! variant table). See python/compile/aot.py.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub rope_base: f64,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_f32: usize,
    pub size_f32: usize,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VariantKind {
    Step,
    /// XLA-fused-attention step (CPU fast path; see EXPERIMENTS §Perf).
    StepFused,
    /// Paged step: K/V read from `[n_blocks, block_size, L, H, dh]` arenas
    /// through per-row block tables (`blocks`/`block` fields set).
    StepPaged,
    Trace,
    Prefill,
    Append,
    Gather,
    Insert,
    /// Paged arena row write: DUS of one `[L, H, dh]` row at a linear slot.
    BlockWrite,
    /// Paged arena row gather: permute all `n_blocks * block_size` rows by a
    /// linear index vector (serves both CoW block copies and compaction).
    BlockGather,
}

impl VariantKind {
    fn parse(s: &str) -> anyhow::Result<VariantKind> {
        Ok(match s {
            "step" => VariantKind::Step,
            "stepf" => VariantKind::StepFused,
            "stepp" => VariantKind::StepPaged,
            "trace" => VariantKind::Trace,
            "prefill" => VariantKind::Prefill,
            "append" => VariantKind::Append,
            "gather" => VariantKind::Gather,
            "insert" => VariantKind::Insert,
            "blockw" => VariantKind::BlockWrite,
            "blockg" => VariantKind::BlockGather,
            other => anyhow::bail!("unknown variant kind '{other}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct Variant {
    pub kind: VariantKind,
    pub name: String,
    pub file: String,
    pub batch: usize,
    pub cache: usize,
    pub prefill: usize,
    /// Paged variants only: arena geometry (0 elsewhere).
    pub blocks: usize,
    pub block: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub charset: String,
    pub model: ModelDims,
    pub weights_file: String,
    pub total_param_f32: usize,
    pub params: Vec<ParamSpec>,
    pub variants: Vec<Variant>,
    pub prefill_bucket: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing manifest: {e}"))?;

        let m = j.req("model").map_err(anyhow::Error::new)?;
        let model = ModelDims {
            vocab: m.usize_at("vocab")?,
            d_model: m.usize_at("d_model")?,
            n_layers: m.usize_at("n_layers")?,
            n_heads: m.usize_at("n_heads")?,
            d_head: m.usize_at("d_head")?,
            d_ff: m.usize_at("d_ff")?,
            rope_base: m.f64_at("rope_base")?,
        };

        let mut params = Vec::new();
        for p in j.arr_at("params")? {
            params.push(ParamSpec {
                name: p.str_at("name")?.to_string(),
                shape: p
                    .arr_at("shape")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                offset_f32: p.usize_at("offset_f32")?,
                size_f32: p.usize_at("size_f32")?,
            });
        }

        let mut variants = Vec::new();
        for v in j.arr_at("variants")? {
            variants.push(Variant {
                kind: VariantKind::parse(v.str_at("kind")?)?,
                name: v.str_at("name")?.to_string(),
                file: v.str_at("file")?.to_string(),
                batch: v.usize_at("batch")?,
                cache: v.usize_at("cache")?,
                prefill: v.usize_at("prefill")?,
                // paged-geometry fields are absent in pre-paging manifests
                blocks: v.get("blocks").and_then(|x| x.as_usize()).unwrap_or(0),
                block: v.get("block").and_then(|x| x.as_usize()).unwrap_or(0),
            });
        }

        Ok(Manifest {
            dir,
            charset: j.str_at("charset")?.to_string(),
            model,
            weights_file: j.str_at("weights_file")?.to_string(),
            total_param_f32: j.usize_at("total_param_f32")?,
            params,
            variants,
            prefill_bucket: j.usize_at("prefill_bucket")?,
        })
    }

    /// Find a variant by kind + engine shape. `prefill` is matched only for
    /// prefill variants.
    pub fn find(&self, kind: VariantKind, batch: usize, cache: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .find(|v| v.kind == kind && v.batch == batch && v.cache == cache)
    }

    /// Find a paged variant by kind + arena geometry (`batch` is matched for
    /// the step; row write/gather executables are batch-free, registered
    /// with batch 0).
    pub fn find_paged(
        &self,
        kind: VariantKind,
        batch: usize,
        n_blocks: usize,
        block_size: usize,
    ) -> Option<&Variant> {
        self.variants.iter().find(|v| {
            v.kind == kind && v.batch == batch && v.blocks == n_blocks && v.block == block_size
        })
    }

    /// All distinct (batch, cache) engine shapes that have a full executable
    /// set (step + append + gather + insert + a prefill at the same cache).
    pub fn engine_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes: Vec<(usize, usize)> = Vec::new();
        for v in self.variants.iter().filter(|v| v.kind == VariantKind::Step) {
            let (b, s) = (v.batch, v.cache);
            let complete = self.find(VariantKind::Append, b, s).is_some()
                && self.find(VariantKind::Gather, b, s).is_some()
                && self.find(VariantKind::Insert, b, s).is_some()
                && self
                    .variants
                    .iter()
                    .any(|p| p.kind == VariantKind::Prefill && p.cache == s);
            if complete && !shapes.contains(&(b, s)) {
                shapes.push((b, s));
            }
        }
        shapes.sort_unstable();
        shapes
    }

    /// Load weights.bin as a flat f32 vec (length-validated).
    pub fn load_weights(&self) -> anyhow::Result<Vec<f32>> {
        let path = self.dir.join(&self.weights_file);
        let raw = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        anyhow::ensure!(
            raw.len() == self.total_param_f32 * 4,
            "weights.bin: expected {} f32 ({} bytes), got {} bytes",
            self.total_param_f32,
            self.total_param_f32 * 4,
            raw.len()
        );
        let mut out = vec![0f32; self.total_param_f32];
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
          "charset": "01 >\n",
          "model": {"vocab": 5, "d_model": 8, "n_layers": 1, "n_heads": 1,
                    "d_head": 8, "d_ff": 16, "rope_base": 10000.0},
          "weights_file": "weights.bin",
          "total_param_f32": 10,
          "params": [{"name": "embed", "shape": [5, 2], "offset_f32": 0, "size_f32": 10}],
          "variants": [
            {"kind": "step", "name": "step_b1_s8", "file": "step_b1_s8.hlo.txt",
             "batch": 1, "cache": 8, "prefill": 0},
            {"kind": "append", "name": "append_b1_s8", "file": "a.hlo.txt",
             "batch": 1, "cache": 8, "prefill": 0},
            {"kind": "gather", "name": "gather_b1_s8", "file": "g.hlo.txt",
             "batch": 1, "cache": 8, "prefill": 0},
            {"kind": "insert", "name": "insert_b1_s8", "file": "i.hlo.txt",
             "batch": 1, "cache": 8, "prefill": 0},
            {"kind": "prefill", "name": "prefill_b1_s8_p4", "file": "p.hlo.txt",
             "batch": 1, "cache": 8, "prefill": 4}
          ],
          "prefill_bucket": 4
        }"#
        .to_string()
    }

    fn write_fixture(dir: &std::path::Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        let w: Vec<u8> = (0..10u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        std::fs::write(dir.join("weights.bin"), w).unwrap();
    }

    #[test]
    fn parse_and_find() {
        let dir = std::env::temp_dir().join("lazyeviction_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 5);
        assert_eq!(m.params[0].name, "embed");
        assert!(m.find(VariantKind::Step, 1, 8).is_some());
        assert!(m.find(VariantKind::Step, 2, 8).is_none());
        assert_eq!(m.engine_shapes(), vec![(1, 8)]);
    }

    #[test]
    fn weights_roundtrip() {
        let dir = std::env::temp_dir().join("lazyeviction_manifest_test2");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), 10);
        assert_eq!(w[3], 3.0);
    }

    #[test]
    fn weights_size_mismatch_rejected() {
        let dir = std::env::temp_dir().join("lazyeviction_manifest_test3");
        write_fixture(&dir);
        std::fs::write(dir.join("weights.bin"), [0u8; 8]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.load_weights().is_err());
    }
}
