//! The model-execution surface the coordinator drives, abstracted from PJRT.
//!
//! `coordinator::Engine` needs five operations (prefill, step, and the three
//! device-side cache maintenance calls) plus shape metadata. Factoring them
//! into [`DecodeBackend`] lets the same decode loop, eviction pass, block
//! pool and scheduler run over:
//!
//! * [`ModelExecutor`](super::executor::ModelExecutor) — the real AOT/PJRT
//!   path (needs compiled artifacts);
//! * [`SimBackend`] — a deterministic, artifact-free toy backend whose
//!   attention statistics are rich enough to exercise TS/MRI tracking,
//!   every eviction policy, pool preemption, and the TCP server end to end.

use anyhow::Result;

use super::executor::{ExecCounts, PrefillOut, StepOut};
use super::manifest::ModelDims;

/// One engine shape's model-execution backend (see module docs).
pub trait DecodeBackend: Send {
    fn dims(&self) -> &ModelDims;
    /// Padded prompt bucket of the prefill executable.
    fn prefill_bucket(&self) -> usize;
    /// Run the batch-1 prefill over a padded prompt.
    fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut>;
    /// Insert a prefilled sequence cache at batch row `row`.
    fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()>;
    /// One decode step over all rows.
    fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut>;
    /// Append this step's K/V rows at per-row slot indices.
    fn append(&mut self, k_new: &[f32], v_new: &[f32], idx: &[i32]) -> Result<()>;
    /// Compact/permute cache slots (the eviction gather).
    fn gather(&mut self, idx: &[i32]) -> Result<()>;
    fn exec_counts(&self) -> ExecCounts;
    /// KV bytes the device-resident caches occupy for this engine.
    fn device_cache_bytes(&self) -> usize;
}

/// Charset of the sim backend (a superset of the reasoning-sample grammar in
/// `trace::workload`, so `gen_reasoning_sample` prompts encode cleanly).
pub const SIM_CHARSET: &str = "#>=;?+*-.0123456789ABCDEFGHIJKLMNOPQRSTUVWXYZ \n";

/// Deterministic artifact-free backend. The "model" is a hash: the next
/// token is a fixed function of (current token, position), and per-slot
/// attention mixes a sub-α floor with sparse super-α spikes, so recurrence
/// tracking and every eviction policy see non-degenerate signals. No PJRT,
/// no weights, no tensors — K/V payloads are zeros (the engine only routes
/// them; policies act on the attention metadata).
pub struct SimBackend {
    batch: usize,
    cache: usize,
    bucket: usize,
    dims: ModelDims,
    counts: ExecCounts,
}

impl SimBackend {
    pub fn new(batch: usize, cache: usize) -> SimBackend {
        SimBackend {
            batch,
            cache,
            bucket: 64,
            dims: ModelDims {
                vocab: SIM_CHARSET.chars().count(),
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_head: 4,
                d_ff: 32,
                rope_base: 10000.0,
            },
            counts: ExecCounts::default(),
        }
    }

    pub fn charset(&self) -> &'static str {
        SIM_CHARSET
    }

    /// Next-token id as a fixed hash of (token, position).
    fn next_id(&self, tok: i32, pos: i32) -> usize {
        let x = (tok as u64)
            .wrapping_mul(1099511628211)
            .wrapping_add((pos as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        ((x >> 17) % self.dims.vocab as u64) as usize
    }

    /// Aggregated attention for a live slot at absolute position `pos`:
    /// ~9% of (slot, pos) pairs spike well above any α, the rest sit on a
    /// sub-α noise floor.
    fn attn_at(slot: usize, pos: i32) -> f32 {
        let x = (slot as u64)
            .wrapping_mul(2654435761)
            .wrapping_add((pos as u64).wrapping_mul(40503));
        let h = x ^ (x >> 13);
        if h % 11 == 0 {
            0.25
        } else {
            1e-6
        }
    }

    fn one_hot(&self, out: &mut [f32], id: usize) {
        debug_assert_eq!(out.len(), self.dims.vocab);
        out.fill(0.0);
        out[id] = 1.0;
    }
}

impl DecodeBackend for SimBackend {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill_bucket(&self) -> usize {
        self.bucket
    }

    fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut> {
        anyhow::ensure!(tokens.len() == self.bucket && valid.len() == self.bucket);
        self.counts.prefill += 1;
        let n = valid.iter().filter(|&&v| v > 0.0).count().max(1);
        let mut attn_last = vec![0f32; self.bucket];
        for (i, a) in attn_last.iter_mut().enumerate().take(n) {
            *a = Self::attn_at(i, (n - 1) as i32);
        }
        let mut logits_last = vec![0f32; self.dims.vocab];
        let id = self.next_id(tokens[n - 1], (n - 1) as i32);
        self.one_hot(&mut logits_last, id);
        let cache_elems = self.dims.n_layers * self.dims.n_heads * self.cache * self.dims.d_head;
        Ok(PrefillOut {
            k_seq: vec![0.0; cache_elems],
            v_seq: vec![0.0; cache_elems],
            attn_last,
            logits_last,
        })
    }

    fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()> {
        let cache_elems = self.dims.n_layers * self.dims.n_heads * self.cache * self.dims.d_head;
        anyhow::ensure!(k_seq.len() == cache_elems && v_seq.len() == cache_elems);
        anyhow::ensure!(row < self.batch, "insert row {row} out of range");
        self.counts.insert += 2;
        Ok(())
    }

    fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(slot_mask.len() == b * s && tokens.len() == b && pos.len() == b);
        self.counts.step += 1;
        let v = self.dims.vocab;
        let mut logits = vec![0f32; b * v];
        let mut attn = vec![0f32; b * s];
        for row in 0..b {
            let id = self.next_id(tokens[row], pos[row]);
            logits[row * v + id] = 1.0;
            for j in 0..s {
                if slot_mask[row * s + j] > 0.0 {
                    attn[row * s + j] = Self::attn_at(j, pos[row]);
                }
            }
        }
        let new_elems = b * self.dims.n_layers * self.dims.n_heads * self.dims.d_head;
        Ok(StepOut {
            logits,
            attn,
            k_new: vec![0.0; new_elems],
            v_new: vec![0.0; new_elems],
        })
    }

    fn append(&mut self, k_new: &[f32], _v_new: &[f32], idx: &[i32]) -> Result<()> {
        let new_elems =
            self.batch * self.dims.n_layers * self.dims.n_heads * self.dims.d_head;
        anyhow::ensure!(idx.len() == self.batch && k_new.len() == new_elems);
        self.counts.append += 2;
        Ok(())
    }

    fn gather(&mut self, idx: &[i32]) -> Result<()> {
        anyhow::ensure!(idx.len() == self.batch * self.cache);
        self.counts.gather += 2;
        Ok(())
    }

    fn exec_counts(&self) -> ExecCounts {
        self.counts
    }

    fn device_cache_bytes(&self) -> usize {
        2 * self.batch
            * self.dims.n_layers
            * self.dims.n_heads
            * self.cache
            * self.dims.d_head
            * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charset_covers_reasoning_grammar() {
        // gen_reasoning_sample emits '#', lowercase? no — uppercase vars,
        // digits, '=', ';', '+', '?', '\n', '>' — all must tokenize
        for c in "#A=3;B+7?\n> ".chars() {
            assert!(SIM_CHARSET.contains(c), "charset missing {c:?}");
        }
    }

    #[test]
    fn step_is_deterministic_and_mask_respecting() {
        let mut b = SimBackend::new(2, 16);
        let mut mask = vec![0f32; 32];
        mask[..5].fill(1.0); // row 0: 5 live slots; row 1 inactive
        let o1 = b.step(&mask, &[3, 0], &[5, 0]).unwrap();
        let o2 = b.step(&mask, &[3, 0], &[5, 0]).unwrap();
        assert_eq!(o1.logits, o2.logits);
        assert_eq!(o1.attn, o2.attn);
        assert_eq!(o1.logits.iter().filter(|&&x| x == 1.0).count(), 2);
        // no attention outside the mask
        assert!(o1.attn[5..16].iter().all(|&x| x == 0.0));
        assert!(o1.attn[16..].iter().all(|&x| x == 0.0));
        assert_eq!(b.exec_counts().step, 2);
    }

    #[test]
    fn attention_has_spikes_and_floor() {
        let mut hot = 0;
        let mut total = 0;
        for pos in 0..200 {
            for slot in 0..64 {
                let a = SimBackend::attn_at(slot, pos);
                total += 1;
                if a > 5e-4 {
                    hot += 1;
                } else {
                    assert!(a < 5e-4);
                }
            }
        }
        let frac = hot as f64 / total as f64;
        assert!(frac > 0.02 && frac < 0.3, "spike fraction {frac}");
    }

    #[test]
    fn prefill_shapes_match_engine_expectations() {
        let mut b = SimBackend::new(1, 32);
        let p = b.prefill_bucket();
        let mut toks = vec![0i32; p];
        let mut valid = vec![0f32; p];
        for i in 0..7 {
            toks[i] = i as i32;
            valid[i] = 1.0;
        }
        let out = b.prefill(&toks, &valid).unwrap();
        assert_eq!(out.logits_last.len(), b.dims().vocab);
        assert_eq!(out.attn_last.len(), p);
        let d = b.dims();
        assert_eq!(out.k_seq.len(), d.n_layers * d.n_heads * 32 * d.d_head);
    }
}
