//! The model-execution surface the coordinator drives, abstracted from PJRT.
//!
//! `coordinator::Engine` needs prefill, a decode step, and cache
//! maintenance, plus shape metadata. Factoring them into [`DecodeBackend`]
//! lets the same decode loop, eviction pass, block pool and scheduler run
//! over:
//!
//! * [`ModelExecutor`](super::executor::ModelExecutor) — the real AOT/PJRT
//!   path (needs compiled artifacts);
//! * [`SimBackend`] — a deterministic, artifact-free toy backend whose
//!   attention statistics are rich enough to exercise TS/MRI tracking,
//!   every eviction policy, pool preemption, and the TCP server end to end.
//!
//! ## Two physical layouts, one trait
//!
//! The trait carries both K/V layouts the engine can run:
//!
//! * **Dense (seed layout)** — per-row `[B, L, H, S, dh]` worst-case cache
//!   buffers; `insert`/`append`/`gather`/`step` address slots directly.
//!   This is the only layout when no block pool is configured.
//! * **Paged** — pool-shaped `[n_blocks, block_size, L, H, dh]` arenas
//!   ([`kvpool::KvArena`](crate::kvpool::KvArena) on the host for the sim,
//!   device buffers of the same shape for PJRT), activated once by
//!   [`DecodeBackend::init_paged`]. Every byte is addressed through a
//!   sequence's block table: rows land block-by-block
//!   ([`write_kv_rows`](DecodeBackend::write_kv_rows)), copy-on-write
//!   duplicates occupied rows ([`copy_block`](DecodeBackend::copy_block)),
//!   eviction compaction relocates survivors
//!   ([`gather_kv_rows`](DecodeBackend::gather_kv_rows) — two-phase, since
//!   keep-lists reorder arbitrarily), and the decode step gathers context
//!   through the flattened block tables
//!   ([`step_paged`](DecodeBackend::step_paged)).
//!
//! ## Invariants / failure modes
//!
//! * A backend in paged mode must not allocate (or keep) any per-row
//!   worst-case K/V buffer — the arena IS the physical KV footprint, and
//!   [`device_cache_bytes`](DecodeBackend::device_cache_bytes) must report
//!   it, so capacity accounting scales with pool blocks rather than
//!   `batch × max_len`.
//! * The engine guarantees ordering: CoW copies are applied before the next
//!   row write, compaction moves before the next pool allocation. Backends
//!   may therefore assume a mapped row's bytes are always current, and the
//!   sim backend *does* — its paged attention derives each slot's identity
//!   from the stored key bytes, so a mis-routed block table or a missed
//!   copy shows up as divergent recurrence tracking in tests rather than
//!   passing silently.
//! * `init_paged` is called at most once, before any prefill/step; calling
//!   dense cache ops (`insert`/`append`/`gather`/`step`) after it is a
//!   contract violation (the sim backend rejects the mixed mode it can
//!   detect cheaply; the executor has no dense buffers to serve them).

use anyhow::Result;

use super::executor::{ExecCounts, PrefillOut, StepOut};
use super::manifest::ModelDims;
use crate::kvpool::{BlockCopy, BlockId, KvArena, KvLayout, RowMove};

/// Prefill outputs in token-major row form for the paged path: row `i` of
/// `k_rows`/`v_rows` is token `i`'s `[L, H, dh]` K/V — ready to scatter into
/// arena blocks through a block table (no `[L, H, S, dh]` worst-case buffer).
#[derive(Debug)]
pub struct PrefillRows {
    /// `[p, L·H·dh]` token-major keys (RoPE applied).
    pub k_rows: Vec<f32>,
    pub v_rows: Vec<f32>,
    /// `[p]` last-prompt-row aggregated attention.
    pub attn_last: Vec<f32>,
    /// `[V]` logits at the last prompt position.
    pub logits_last: Vec<f32>,
}

/// One engine shape's model-execution backend (see module docs).
pub trait DecodeBackend: Send {
    fn dims(&self) -> &ModelDims;
    /// Padded prompt bucket of the prefill executable.
    fn prefill_bucket(&self) -> usize;
    /// Run the batch-1 prefill over a padded prompt (dense layout).
    fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut>;
    /// Insert a prefilled sequence cache at batch row `row` (dense layout).
    fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()>;
    /// One decode step over all rows (dense layout).
    fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut>;
    /// Append this step's K/V rows at per-row slot indices (dense layout).
    fn append(&mut self, k_new: &[f32], v_new: &[f32], idx: &[i32]) -> Result<()>;
    /// Compact/permute cache slots (the eviction gather, dense layout).
    fn gather(&mut self, idx: &[i32]) -> Result<()>;
    fn exec_counts(&self) -> ExecCounts;
    /// KV bytes the device-resident caches occupy for this engine — the
    /// whole arena in paged mode, the dense buffers otherwise.
    fn device_cache_bytes(&self) -> usize;

    // --- physical paging (see module docs) ---

    /// Switch to pool-shaped K/V storage: allocate the
    /// `[n_blocks, block_size, L, H, dh]` arenas and retire any dense
    /// per-row buffers. Called once, before any prefill or step.
    fn init_paged(&mut self, n_blocks: usize, block_size: usize) -> Result<()>;

    /// Has `init_paged` been applied?
    fn is_paged(&self) -> bool;

    /// Paged prefill: token-major rows instead of a worst-case `[L,H,S,dh]`
    /// buffer. The caller scatters the rows through the row's block table.
    ///
    /// The token stream is NOT required to be a prompt: recompute-mode
    /// preemption resume feeds `prompt ++ generated` through this same
    /// entry point to re-materialize a mid-sequence row in one pass. Both
    /// implementations honor that contract for free because prefill K/V is
    /// a function of (token, position) only — row `i` of the output must be
    /// byte-identical to what a decode step would have produced for the
    /// same token at position `i` (the sim test
    /// `prefill_rows_recompute_matches_decode_rows` pins this; on the PJRT
    /// path both run the same RoPE/projection weights).
    fn prefill_rows(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillRows>;

    /// Write token-major `[n, L·H·dh]` K/V rows at `(block, offset)`.
    /// The span must stay inside the block.
    fn write_kv_rows(
        &mut self,
        block: BlockId,
        offset: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()>;

    /// Apply a copy-on-write: duplicate `copy.rows` leading rows of block
    /// `copy.src` into `copy.dst`.
    fn copy_block(&mut self, copy: BlockCopy) -> Result<()>;

    /// Apply an eviction compaction: relocate every surviving row. Two-phase
    /// (all sources read before any destination is written).
    fn gather_kv_rows(&mut self, moves: &[RowMove]) -> Result<()>;

    /// One decode step reading K/V through per-row block tables.
    /// `block_tables` is `[B, blocks_per_row]` row-major (block ids; entries
    /// past a row's mapped blocks are ignored), `seq_lens[r]` the row's live
    /// token count (0 = inactive row). Output shapes match
    /// [`step`](DecodeBackend::step) (attention padded to `[B, S]`, live
    /// slots `[0, len)`).
    fn step_paged(
        &mut self,
        block_tables: &[i32],
        blocks_per_row: usize,
        seq_lens: &[i32],
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<StepOut>;

    // --- host tier (kvtier) swap surface ---

    /// Read the leading `rows` occupied rows of a block out of the arena as
    /// token-major `[rows, L·H·dh]` K and V payloads — the device→host half
    /// of a demotion/swap-out. Must not mutate the arena; callers rely on
    /// the bytes staying valid until the next write/move lands (the engine
    /// swaps out *before* applying a compaction's `RowMove` list).
    fn swap_out_block(&mut self, block: BlockId, rows: usize) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Write token-major `[rows, L·H·dh]` K/V payloads back into a block
    /// starting at offset 0 — the host→device half of a promotion/swap-in.
    /// Row count is implied by the payload length. A swap-in after a
    /// swap-out of the same rows must be byte-identical (round-trip
    /// contract; the sim backend's stored-key identity check makes a
    /// corrupted round trip fail recurrence tests rather than pass silently).
    fn swap_in_block(&mut self, block: BlockId, k_rows: &[f32], v_rows: &[f32]) -> Result<()>;

    /// Test/debug introspection: the K/V bytes stored at an arena location,
    /// when the backend can read them cheaply (`None` otherwise — e.g. a
    /// device-resident arena off the hot path).
    fn debug_kv_row(&self, block: BlockId, offset: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let _ = (block, offset);
        None
    }
}

/// Charset of the sim backend (a superset of the reasoning-sample grammar in
/// `trace::workload`, so `gen_reasoning_sample` prompts encode cleanly).
pub const SIM_CHARSET: &str = "#>=;?+*-.0123456789ABCDEFGHIJKLMNOPQRSTUVWXYZ \n";

/// Deterministic artifact-free backend. The "model" is a hash: the next
/// token is a fixed function of (current token, position), and per-slot
/// attention mixes a sub-α floor with sparse super-α spikes, so recurrence
/// tracking and every eviction policy see non-degenerate signals. No PJRT
/// and no weights, but K/V payloads are *real bytes*: each token's row is a
/// deterministic function of (token, birth position), with the birth
/// position recoverable from `k_row[0]`. In paged mode the rows live in a
/// pool-shaped [`KvArena`] and the step's attention reads each slot's
/// identity back out of the stored keys — so block-table routing, CoW and
/// compaction are load-bearing, not decorative.
pub struct SimBackend {
    batch: usize,
    cache: usize,
    bucket: usize,
    dims: ModelDims,
    counts: ExecCounts,
    /// Physical paged K/V storage (present iff `init_paged` ran).
    arena: Option<KvArena>,
}

impl SimBackend {
    pub fn new(batch: usize, cache: usize) -> SimBackend {
        SimBackend {
            batch,
            cache,
            bucket: 64,
            dims: ModelDims {
                vocab: SIM_CHARSET.chars().count(),
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_head: 4,
                d_ff: 32,
                rope_base: 10000.0,
            },
            counts: ExecCounts::default(),
            arena: None,
        }
    }

    pub fn charset(&self) -> &'static str {
        SIM_CHARSET
    }

    fn row_elems(&self) -> usize {
        self.dims.n_layers * self.dims.n_heads * self.dims.d_head
    }

    /// Next-token id as a fixed hash of (token, position).
    fn next_id(&self, tok: i32, pos: i32) -> usize {
        let x = (tok as u64)
            .wrapping_mul(1099511628211)
            .wrapping_add((pos as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        ((x >> 17) % self.dims.vocab as u64) as usize
    }

    /// Aggregated attention paid at query position `pos` to the token *born*
    /// at `birth`: ~9% of pairs spike well above any α, the rest sit on a
    /// sub-α noise floor. (Dense mode keys this by slot index; before any
    /// eviction the two coincide.)
    fn attn_at(birth: usize, pos: i32) -> f32 {
        let x = (birth as u64)
            .wrapping_mul(2654435761)
            .wrapping_add((pos as u64).wrapping_mul(40503));
        let h = x ^ (x >> 13);
        if h % 11 == 0 {
            0.25
        } else {
            1e-6
        }
    }

    fn fill(tok: i32, pos: i32, j: usize, salt: u64) -> f32 {
        let x = (tok as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((pos as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add((j as u64).wrapping_mul(0x1656_67B1_9E37_79F9))
            .wrapping_add(salt);
        let h = (x ^ (x >> 29)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        ((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5
    }

    /// Fill one token's `[L, H, dh]` K and V rows. `k[0]` carries the birth
    /// position (the identity paged attention recovers from storage),
    /// `k[1]` the token id; everything else is hash noise.
    fn kv_row_into(k: &mut [f32], v: &mut [f32], tok: i32, pos: i32) {
        for (j, x) in k.iter_mut().enumerate() {
            *x = Self::fill(tok, pos, j, 0x51);
        }
        k[0] = pos as f32;
        k[1] = tok as f32;
        for (j, x) in v.iter_mut().enumerate() {
            *x = Self::fill(tok, pos, j, 0xA7);
        }
    }

    fn one_hot(&self, out: &mut [f32], id: usize) {
        debug_assert_eq!(out.len(), self.dims.vocab);
        out.fill(0.0);
        out[id] = 1.0;
    }

    /// Shared prefill math: per-token rows + last-row attention + logits.
    fn prefill_core(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillRows> {
        anyhow::ensure!(tokens.len() == self.bucket && valid.len() == self.bucket);
        self.counts.prefill += 1;
        let n = valid.iter().filter(|&&v| v > 0.0).count().max(1);
        let re = self.row_elems();
        let mut k_rows = vec![0f32; n * re];
        let mut v_rows = vec![0f32; n * re];
        for i in 0..n {
            Self::kv_row_into(
                &mut k_rows[i * re..(i + 1) * re],
                &mut v_rows[i * re..(i + 1) * re],
                tokens[i],
                i as i32,
            );
        }
        let mut attn_last = vec![0f32; n];
        for (i, a) in attn_last.iter_mut().enumerate() {
            *a = Self::attn_at(i, (n - 1) as i32);
        }
        let mut logits_last = vec![0f32; self.dims.vocab];
        let id = self.next_id(tokens[n - 1], (n - 1) as i32);
        self.one_hot(&mut logits_last, id);
        Ok(PrefillRows {
            k_rows,
            v_rows,
            attn_last,
            logits_last,
        })
    }
}

impl DecodeBackend for SimBackend {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill_bucket(&self) -> usize {
        self.bucket
    }

    fn prefill(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillOut> {
        anyhow::ensure!(self.arena.is_none(), "dense prefill on a paged backend");
        let rows = self.prefill_core(tokens, valid)?;
        let n = rows.attn_last.len();
        let (l, h, dh) = (self.dims.n_layers, self.dims.n_heads, self.dims.d_head);
        let s = self.cache;
        let re = self.row_elems();
        // scatter token-major rows into the dense [L, H, S, dh] layout
        let mut k_seq = vec![0f32; l * h * s * dh];
        let mut v_seq = vec![0f32; l * h * s * dh];
        for i in 0..n {
            for li in 0..l {
                for hi in 0..h {
                    let src = i * re + (li * h + hi) * dh;
                    let dst = ((li * h + hi) * s + i) * dh;
                    k_seq[dst..dst + dh].copy_from_slice(&rows.k_rows[src..src + dh]);
                    v_seq[dst..dst + dh].copy_from_slice(&rows.v_rows[src..src + dh]);
                }
            }
        }
        let mut attn_last = vec![0f32; self.bucket];
        attn_last[..n].copy_from_slice(&rows.attn_last);
        Ok(PrefillOut {
            k_seq,
            v_seq,
            attn_last,
            logits_last: rows.logits_last,
        })
    }

    fn insert(&mut self, k_seq: &[f32], v_seq: &[f32], row: usize) -> Result<()> {
        anyhow::ensure!(self.arena.is_none(), "dense insert on a paged backend");
        let cache_elems = self.dims.n_layers * self.dims.n_heads * self.cache * self.dims.d_head;
        anyhow::ensure!(k_seq.len() == cache_elems && v_seq.len() == cache_elems);
        anyhow::ensure!(row < self.batch, "insert row {row} out of range");
        self.counts.insert += 2;
        Ok(())
    }

    fn step(&mut self, slot_mask: &[f32], tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        anyhow::ensure!(self.arena.is_none(), "dense step on a paged backend");
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(slot_mask.len() == b * s && tokens.len() == b && pos.len() == b);
        self.counts.step += 1;
        let v = self.dims.vocab;
        let mut logits = vec![0f32; b * v];
        let mut attn = vec![0f32; b * s];
        for row in 0..b {
            let id = self.next_id(tokens[row], pos[row]);
            logits[row * v + id] = 1.0;
            for j in 0..s {
                if slot_mask[row * s + j] > 0.0 {
                    attn[row * s + j] = Self::attn_at(j, pos[row]);
                }
            }
        }
        let re = self.row_elems();
        let mut k_new = vec![0f32; b * re];
        let mut v_new = vec![0f32; b * re];
        for row in 0..b {
            Self::kv_row_into(
                &mut k_new[row * re..(row + 1) * re],
                &mut v_new[row * re..(row + 1) * re],
                tokens[row],
                pos[row],
            );
        }
        Ok(StepOut {
            logits,
            attn,
            k_new,
            v_new,
        })
    }

    fn append(&mut self, k_new: &[f32], _v_new: &[f32], idx: &[i32]) -> Result<()> {
        anyhow::ensure!(self.arena.is_none(), "dense append on a paged backend");
        let new_elems = self.batch * self.row_elems();
        anyhow::ensure!(idx.len() == self.batch && k_new.len() == new_elems);
        self.counts.append += 2;
        Ok(())
    }

    fn gather(&mut self, idx: &[i32]) -> Result<()> {
        anyhow::ensure!(self.arena.is_none(), "dense gather on a paged backend");
        anyhow::ensure!(idx.len() == self.batch * self.cache);
        self.counts.gather += 2;
        Ok(())
    }

    fn exec_counts(&self) -> ExecCounts {
        self.counts
    }

    fn device_cache_bytes(&self) -> usize {
        match &self.arena {
            // paged: the arena is the entire physical KV footprint
            Some(a) => a.bytes(),
            None => {
                2 * self.batch
                    * self.dims.n_layers
                    * self.dims.n_heads
                    * self.cache
                    * self.dims.d_head
                    * 4
            }
        }
    }

    fn init_paged(&mut self, n_blocks: usize, block_size: usize) -> Result<()> {
        anyhow::ensure!(self.arena.is_none(), "init_paged called twice");
        self.arena = Some(KvArena::new(
            n_blocks,
            block_size,
            KvLayout {
                n_layers: self.dims.n_layers,
                n_heads: self.dims.n_heads,
                d_head: self.dims.d_head,
            },
        ));
        Ok(())
    }

    fn is_paged(&self) -> bool {
        self.arena.is_some()
    }

    fn prefill_rows(&mut self, tokens: &[i32], valid: &[f32]) -> Result<PrefillRows> {
        anyhow::ensure!(self.arena.is_some(), "prefill_rows before init_paged");
        self.prefill_core(tokens, valid)
    }

    fn write_kv_rows(
        &mut self,
        block: BlockId,
        offset: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()> {
        let re = self.row_elems();
        let arena = self.arena.as_mut().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        arena.write_rows(block, offset, k_rows, v_rows);
        self.counts.row_writes += (k_rows.len() / re) as u64;
        Ok(())
    }

    fn copy_block(&mut self, copy: BlockCopy) -> Result<()> {
        let arena = self.arena.as_mut().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        arena.copy_block(copy);
        self.counts.block_copies += 1;
        Ok(())
    }

    fn gather_kv_rows(&mut self, moves: &[RowMove]) -> Result<()> {
        let arena = self.arena.as_mut().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        arena.gather_rows(moves);
        self.counts.row_moves += moves.len() as u64;
        Ok(())
    }

    fn step_paged(
        &mut self,
        block_tables: &[i32],
        blocks_per_row: usize,
        seq_lens: &[i32],
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<StepOut> {
        let (b, s) = (self.batch, self.cache);
        anyhow::ensure!(
            block_tables.len() == b * blocks_per_row
                && seq_lens.len() == b
                && tokens.len() == b
                && pos.len() == b
        );
        let arena = self.arena.as_ref().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        let bs = arena.block_size();
        self.counts.step += 1;
        let v = self.dims.vocab;
        let mut logits = vec![0f32; b * v];
        let mut attn = vec![0f32; b * s];
        for row in 0..b {
            let id = self.next_id(tokens[row], pos[row]);
            logits[row * v + id] = 1.0;
            let len = seq_lens[row] as usize;
            anyhow::ensure!(len <= s, "row {row} len {len} exceeds cache {s}");
            anyhow::ensure!(len <= blocks_per_row * bs, "row {row} len {len} unmapped");
            for j in 0..len {
                let bi = block_tables[row * blocks_per_row + j / bs];
                anyhow::ensure!(bi >= 0, "row {row} slot {j}: unmapped block");
                // the slot's identity comes from the STORED key bytes — the
                // whole point: a wrong block table or missed CoW/compaction
                // copy changes the attention signal and fails tests
                let birth = arena.k_row(bi as BlockId, j % bs)[0] as usize;
                attn[row * s + j] = Self::attn_at(birth, pos[row]);
            }
        }
        let re = self.row_elems();
        let mut k_new = vec![0f32; b * re];
        let mut v_new = vec![0f32; b * re];
        for row in 0..b {
            Self::kv_row_into(
                &mut k_new[row * re..(row + 1) * re],
                &mut v_new[row * re..(row + 1) * re],
                tokens[row],
                pos[row],
            );
        }
        Ok(StepOut {
            logits,
            attn,
            k_new,
            v_new,
        })
    }

    fn swap_out_block(&mut self, block: BlockId, rows: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let arena = self.arena.as_ref().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        anyhow::ensure!(rows <= arena.block_size(), "swap-out rows exceed block");
        let re = arena.row_elems();
        let mut k = Vec::with_capacity(rows * re);
        let mut v = Vec::with_capacity(rows * re);
        for off in 0..rows {
            k.extend_from_slice(arena.k_row(block, off));
            v.extend_from_slice(arena.v_row(block, off));
        }
        self.counts.block_swap_outs += 1;
        Ok((k, v))
    }

    fn swap_in_block(&mut self, block: BlockId, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        let arena = self.arena.as_mut().ok_or_else(|| anyhow::anyhow!("not paged"))?;
        arena.write_rows(block, 0, k_rows, v_rows);
        self.counts.block_swap_ins += 1;
        Ok(())
    }

    fn debug_kv_row(&self, block: BlockId, offset: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        self.arena
            .as_ref()
            .map(|a| (a.k_row(block, offset).to_vec(), a.v_row(block, offset).to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charset_covers_reasoning_grammar() {
        // gen_reasoning_sample emits '#', lowercase? no — uppercase vars,
        // digits, '=', ';', '+', '?', '\n', '>' — all must tokenize
        for c in "#A=3;B+7?\n> ".chars() {
            assert!(SIM_CHARSET.contains(c), "charset missing {c:?}");
        }
    }

    #[test]
    fn step_is_deterministic_and_mask_respecting() {
        let mut b = SimBackend::new(2, 16);
        let mut mask = vec![0f32; 32];
        mask[..5].fill(1.0); // row 0: 5 live slots; row 1 inactive
        let o1 = b.step(&mask, &[3, 0], &[5, 0]).unwrap();
        let o2 = b.step(&mask, &[3, 0], &[5, 0]).unwrap();
        assert_eq!(o1.logits, o2.logits);
        assert_eq!(o1.attn, o2.attn);
        assert_eq!(o1.logits.iter().filter(|&&x| x == 1.0).count(), 2);
        // no attention outside the mask
        assert!(o1.attn[5..16].iter().all(|&x| x == 0.0));
        assert!(o1.attn[16..].iter().all(|&x| x == 0.0));
        assert_eq!(b.exec_counts().step, 2);
    }

    #[test]
    fn attention_has_spikes_and_floor() {
        let mut hot = 0;
        let mut total = 0;
        for pos in 0..200 {
            for slot in 0..64 {
                let a = SimBackend::attn_at(slot, pos);
                total += 1;
                if a > 5e-4 {
                    hot += 1;
                } else {
                    assert!(a < 5e-4);
                }
            }
        }
        let frac = hot as f64 / total as f64;
        assert!(frac > 0.02 && frac < 0.3, "spike fraction {frac}");
    }

    #[test]
    fn prefill_shapes_match_engine_expectations() {
        let mut b = SimBackend::new(1, 32);
        let p = b.prefill_bucket();
        let mut toks = vec![0i32; p];
        let mut valid = vec![0f32; p];
        for i in 0..7 {
            toks[i] = i as i32;
            valid[i] = 1.0;
        }
        let out = b.prefill(&toks, &valid).unwrap();
        assert_eq!(out.logits_last.len(), b.dims().vocab);
        assert_eq!(out.attn_last.len(), p);
        let d = b.dims();
        assert_eq!(out.k_seq.len(), d.n_layers * d.n_heads * 32 * d.d_head);
        // K rows carry real bytes now: slot 3's layer-0 head-0 lane encodes
        // (pos, token) — the identity the paged path reads back from storage
        let s = 32;
        let dh = d.d_head;
        let slot3_l0h0 = &out.k_seq[3 * dh..3 * dh + dh];
        assert_eq!(slot3_l0h0[0], 3.0, "k_row[0] = birth pos");
        assert_eq!(slot3_l0h0[1], 3.0, "k_row[1] = token id");
        // padding slots stay zero
        assert!(out.k_seq[7 * dh..s * dh].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn paged_rows_match_dense_prefill_bytes() {
        // the same prompt must produce identical K/V bytes through either
        // layout — that equality is what lets a partial prefix hit skip
        // re-writing the shared blocks
        let mut dense = SimBackend::new(1, 32);
        let mut paged = SimBackend::new(1, 32);
        paged.init_paged(4, 8).unwrap();
        let p = dense.prefill_bucket();
        let mut toks = vec![0i32; p];
        let mut valid = vec![0f32; p];
        for i in 0..5 {
            toks[i] = (i + 2) as i32;
            valid[i] = 1.0;
        }
        let d = dense.prefill(&toks, &valid).unwrap();
        let r = paged.prefill_rows(&toks, &valid).unwrap();
        assert_eq!(r.attn_last.len(), 5);
        assert_eq!(&d.attn_last[..5], &r.attn_last[..]);
        assert_eq!(d.logits_last, r.logits_last);
        let dims = dense.dims().clone();
        let (h, dh, s) = (dims.n_heads, dims.d_head, 32);
        let re = dims.n_layers * h * dh;
        // token 4, layer 1, head 1 must match across layouts
        let (li, hi, i) = (1, 1, 4);
        let from_rows = &r.k_rows[i * re + (li * h + hi) * dh..][..dh];
        let from_seq = &d.k_seq[((li * h + hi) * s + i) * dh..][..dh];
        assert_eq!(from_rows, from_seq);
    }

    #[test]
    fn paged_step_reads_identity_through_block_table() {
        let mut b = SimBackend::new(1, 16);
        b.init_paged(4, 4).unwrap();
        let re = b.row_elems();
        // write 6 tokens through a table mapping blocks [2, 0]
        for i in 0..6 {
            let mut k = vec![0f32; re];
            let mut v = vec![0f32; re];
            SimBackend::kv_row_into(&mut k, &mut v, 9, i as i32);
            let (blk, off) = if i < 4 { (2u32, i) } else { (0u32, i - 4) };
            b.write_kv_rows(blk, off, &k, &v).unwrap();
        }
        let tables = vec![2i32, 0, -1, -1];
        let out = b.step_paged(&tables, 4, &[6], &[3], &[6]).unwrap();
        for j in 0..6 {
            assert_eq!(out.attn[j], SimBackend::attn_at(j, 6), "slot {j}");
        }
        assert!(out.attn[6..].iter().all(|&x| x == 0.0));
        // identical to a dense step over the same live set (pre-eviction)
        let mut dense = SimBackend::new(1, 16);
        let mut mask = vec![0f32; 16];
        mask[..6].fill(1.0);
        let od = dense.step(&mask, &[3], &[6]).unwrap();
        assert_eq!(od.attn, out.attn);
        assert_eq!(od.logits, out.logits);
        assert_eq!(od.k_new, out.k_new);
    }

    #[test]
    fn paged_copy_and_gather_move_real_bytes() {
        let mut b = SimBackend::new(1, 16);
        b.init_paged(4, 4).unwrap();
        let re = b.row_elems();
        let mk = |tok: i32, pos: i32| {
            let mut k = vec![0f32; re];
            let mut v = vec![0f32; re];
            SimBackend::kv_row_into(&mut k, &mut v, tok, pos);
            (k, v)
        };
        let (k0, v0) = mk(1, 0);
        let (k1, v1) = mk(2, 1);
        b.write_kv_rows(0, 0, &k0, &v0).unwrap();
        b.write_kv_rows(0, 1, &k1, &v1).unwrap();
        b.copy_block(BlockCopy { src: 0, dst: 3, rows: 2 }).unwrap();
        assert_eq!(b.debug_kv_row(3, 1).unwrap().0, k1);
        b.gather_kv_rows(&[RowMove {
            src_block: 3,
            src_off: 1,
            dst_block: 3,
            dst_off: 0,
        }])
        .unwrap();
        assert_eq!(b.debug_kv_row(3, 0).unwrap().0, k1);
        assert_eq!(b.debug_kv_row(3, 0).unwrap().1, v1);
        // the original block is untouched
        assert_eq!(b.debug_kv_row(0, 0).unwrap().0, k0);
        let c = b.exec_counts();
        assert_eq!(c.block_copies, 1);
        assert_eq!(c.row_moves, 1);
    }

    #[test]
    fn prefill_rows_recompute_matches_decode_rows() {
        // Recompute-mode resume re-prefills prompt + generated tokens in one
        // pass; the rows it writes back must be byte-identical to the rows
        // the original decode steps wrote. Decode writes kv_row_into(tok,
        // pos) for the token fed at pos — so prefilling the same fed stream
        // must reproduce exactly those bytes at every position.
        let mut b = SimBackend::new(1, 32);
        b.init_paged(8, 8).unwrap();
        let p = b.prefill_bucket();
        // a "mid-sequence" stream: 5 prompt tokens + 4 generated ones
        let fed: Vec<i32> = vec![3, 9, 4, 1, 7, 22, 13, 8, 30];
        let mut toks = vec![0i32; p];
        let mut valid = vec![0f32; p];
        for (i, &t) in fed.iter().enumerate() {
            toks[i] = t;
            valid[i] = 1.0;
        }
        let rows = b.prefill_rows(&toks, &valid).unwrap();
        let re = b.row_elems();
        assert_eq!(rows.k_rows.len(), fed.len() * re);
        for (i, &t) in fed.iter().enumerate() {
            let mut k = vec![0f32; re];
            let mut v = vec![0f32; re];
            SimBackend::kv_row_into(&mut k, &mut v, t, i as i32);
            assert_eq!(&rows.k_rows[i * re..(i + 1) * re], &k[..], "K row {i}");
            assert_eq!(&rows.v_rows[i * re..(i + 1) * re], &v[..], "V row {i}");
        }
    }

    #[test]
    fn swap_round_trip_is_byte_identical() {
        // the kvtier contract: swap_out → swap_in restores exactly the
        // bytes, including the stored-key identity the paged attention
        // reads back (k_row[0] = birth pos)
        let mut b = SimBackend::new(1, 16);
        b.init_paged(4, 4).unwrap();
        let re = b.row_elems();
        let mut want_k = Vec::new();
        let mut want_v = Vec::new();
        for i in 0..3 {
            let mut k = vec![0f32; re];
            let mut v = vec![0f32; re];
            SimBackend::kv_row_into(&mut k, &mut v, 7 + i as i32, i as i32);
            b.write_kv_rows(1, i, &k, &v).unwrap();
            want_k.extend_from_slice(&k);
            want_v.extend_from_slice(&v);
        }
        let (k, v) = b.swap_out_block(1, 3).unwrap();
        assert_eq!(k, want_k);
        assert_eq!(v, want_v);
        // clobber the block, then swap the bytes back into another block
        let junk = vec![9.0f32; re];
        b.write_kv_rows(1, 0, &junk, &junk).unwrap();
        b.swap_in_block(3, &k, &v).unwrap();
        for i in 0..3 {
            let (rk, rv) = b.debug_kv_row(3, i).unwrap();
            assert_eq!(rk, want_k[i * re..(i + 1) * re]);
            assert_eq!(rv, want_v[i * re..(i + 1) * re]);
            assert_eq!(rk[0] as usize, i, "birth identity survives the trip");
        }
        let c = b.exec_counts();
        assert_eq!(c.block_swap_outs, 1);
        assert_eq!(c.block_swap_ins, 1);
    }

    #[test]
    fn paged_backend_rejects_dense_ops() {
        let mut b = SimBackend::new(1, 16);
        b.init_paged(4, 4).unwrap();
        assert!(b.step(&[0f32; 16], &[0], &[0]).is_err());
        assert!(b.gather(&[0i32; 16]).is_err());
        // arena bytes replace the dense worst-case in accounting
        assert_eq!(b.device_cache_bytes(), 2 * 4 * 4 * (2 * 2 * 4) * 4);
    }
}
