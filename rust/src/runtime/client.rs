//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text* (python/compile/aot.py): jax >= 0.5 emits
//! HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly (see /opt/xla-example/README.md).
//!
//! ## Upload-safety gotcha (hard-won)
//! `PjRtClient::buffer_from_host_literal` maps to `BufferFromHostLiteral`,
//! which is **asynchronous**: the literal must outlive the device copy, but
//! the crate returns immediately and Rust drops the temporary — a
//! use-after-free that corrupts uploads nondeterministically (we observed
//! both segfaults and `literal.size_bytes() == b->size()` check failures).
//! All uploads here therefore go through `buffer_from_host_buffer`, whose C
//! shim uses `HostBufferSemantics::kImmutableOnlyDuringCall` — a synchronous
//! copy. (`execute::<Literal>` is safe too: its shim awaits the transfer.)

use std::path::Path;

use anyhow::{Context, Result};

pub struct Client {
    inner: xla::PjRtClient,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        Ok(Client {
            inner: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_file(&self, path: impl AsRef<Path>) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.inner
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Upload a host f32 tensor as a device buffer (synchronous copy).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.inner
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    /// Upload a host i32 tensor as a device buffer (synchronous copy).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.inner
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading i32 buffer")
    }

    /// Upload a scalar i32.
    pub fn upload_i32_scalar(&self, x: i32) -> Result<xla::PjRtBuffer> {
        self.inner
            .buffer_from_host_buffer(&[x], &[], None)
            .context("uploading i32 scalar")
    }
}

/// Build an f32 literal with the given logical dims (test helpers / the
/// literal-based `execute` path, which synchronizes internally).
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(
        n as usize == data.len(),
        "literal_f32: {} elements for dims {:?}",
        data.len(),
        dims
    );
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal with the given logical dims.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(
        n as usize == data.len(),
        "literal_i32: {} elements for dims {:?}",
        data.len(),
        dims
    );
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}
