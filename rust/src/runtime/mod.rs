//! PJRT runtime: load AOT artifacts (HLO text) and run them on the hot path.
//!
//! `Client` wraps the PJRT CPU client; `Manifest` is the compile-path
//! contract; `ModelExecutor` serves one (batch, cache) engine shape with
//! device-resident KV buffers. Python never runs at request time.
//!
//! `backend::DecodeBackend` abstracts the execution surface: the PJRT
//! executor and the deterministic artifact-free `SimBackend` both implement
//! it, so the coordinator/scheduler/pool stack is testable without AOT
//! artifacts.

pub mod backend;
pub mod client;
pub mod executor;
pub mod manifest;

pub use backend::{DecodeBackend, PrefillRows, SimBackend, SIM_CHARSET};
pub use client::Client;
pub use executor::{ModelExecutor, PrefillOut, StepOut};
pub use manifest::{Manifest, ModelDims, Variant, VariantKind};
