//! Thread-safe admission queue shared between the server front-end and the
//! engine thread (std sync primitives; tokio is not in the offline set).
//!
//! Ordering is two lanes:
//!
//! * **Front lane** — strict FIFO, populated by `push_front` /
//!   `push_front_all`. Requests the engine declined under pool pressure and
//!   preemption victims go here and always pop before anything else, in the
//!   exact order they were handed back (oldest victim first). They already
//!   paid for their place in line — SLO classes never reorder them.
//! * **Deadline lane** — fresh `push` arrivals, popped
//!   earliest-deadline-first. A request's deadline is its effective enqueue
//!   time (arrival minus any queue wait already accumulated across earlier
//!   admissions, [`PreemptedState::queued_s`]) plus its class's TTFT target.
//!   Within one class this degenerates to FIFO; across classes an
//!   interactive request overtakes batch work until the batch request has
//!   aged past the target gap — aging is built into the deadline, so
//!   nothing starves forever.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::PreemptedState;

/// Service-level class for TTFT-priority admission. Parsed from the wire
/// request's `"class"` field; defaults to [`SloClass::Standard`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SloClass {
    /// Human-in-the-loop: first token matters most.
    Interactive,
    /// Ordinary API traffic.
    #[default]
    Standard,
    /// Offline/bulk work: happy to wait behind everything else.
    Batch,
}

impl SloClass {
    /// TTFT target in seconds — the deadline offset added to the effective
    /// enqueue time. The absolute values matter less than the gaps: a batch
    /// request overtakes a fresh interactive one only after waiting the
    /// difference of the two targets.
    pub fn ttft_target_s(self) -> f64 {
        match self {
            SloClass::Interactive => 0.05,
            SloClass::Standard => 2.0,
            SloClass::Batch => 30.0,
        }
    }

    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }
}

#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub prompt: String,
    /// Forced-continuation template: after the prompt the engine feeds these
    /// chars as inputs; `?` marks holes the model must fill (answer digits).
    /// Empty ⇒ free-running generation.
    pub template: String,
    pub max_new: usize,
    /// SLO class driving deadline-ordered admission. Survives preemption
    /// round trips (the serve loop re-queues with the original class).
    pub class: SloClass,
    /// When this request (re-)entered the queue. For a preempted request
    /// this is the re-queue time; the wait accumulated before earlier
    /// admissions travels inside `resume` (`PreemptedState::queued_s`), so
    /// wait-latency metrics always cover the full queued time.
    pub queued_at: Instant,
    /// Recompute-mode resume state for a preempted request (None for fresh
    /// submissions). Rides the queue round trip back into `Engine::submit`;
    /// `Arc` keeps the per-admission-attempt clone a refcount bump.
    pub resume: Option<Arc<PreemptedState>>,
    /// Trace context assigned at the listener (`telemetry::span`): the
    /// request's root span, which every engine-side span links under.
    /// Default (`trace == 0`) means tracing is off — no span is recorded
    /// anywhere downstream.
    pub span: crate::telemetry::SpanContext,
}

impl QueuedRequest {
    /// Queue wait already accumulated across earlier admission attempts.
    fn prior_wait_s(&self) -> f64 {
        self.resume.as_ref().map(|s| s.queued_s).unwrap_or(0.0)
    }
}

struct Entry {
    /// Monotone insertion counter — the deadline tie-break, which is what
    /// makes same-class ordering exactly FIFO.
    seq: u64,
    /// Deadline in seconds relative to the queue's epoch.
    deadline_s: f64,
    req: QueuedRequest,
}

struct Inner {
    front: VecDeque<QueuedRequest>,
    lane: Vec<Entry>,
    next_seq: u64,
    closed: bool,
}

/// MPSC-ish blocking queue with close semantics and two-lane ordering.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    epoch: Instant,
}

impl Default for RequestQueue {
    fn default() -> Self {
        Self::new()
    }
}

/// Signed seconds from `epoch` to `t` (tests construct past instants).
fn secs_from(epoch: Instant, t: Instant) -> f64 {
    match t.checked_duration_since(epoch) {
        Some(d) => d.as_secs_f64(),
        None => -epoch.duration_since(t).as_secs_f64(),
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner {
                front: VecDeque::new(),
                lane: Vec::new(),
                next_seq: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            epoch: Instant::now(),
        }
    }

    pub fn push(&self, req: QueuedRequest) {
        let deadline_s = secs_from(self.epoch, req.queued_at) - req.prior_wait_s()
            + req.class.ttft_target_s();
        let mut g = self.inner.lock().unwrap();
        let seq = g.next_seq;
        g.next_seq += 1;
        g.lane.push(Entry {
            seq,
            deadline_s,
            req,
        });
        self.cv.notify_one();
    }

    /// Put a request at the *front* of the queue — used to hand back a
    /// request the engine declined under pool pressure, or one whose row was
    /// preempted, so it is first in line once blocks free up. Front-lane
    /// requests pop before any deadline-lane request regardless of class.
    pub fn push_front(&self, req: QueuedRequest) {
        let mut g = self.inner.lock().unwrap();
        g.front.push_front(req);
        self.cv.notify_one();
    }

    /// Put several requests at the front of the queue *preserving slice
    /// order*: `reqs[0]` pops first. This is the re-queue path for
    /// same-step preemption victims — `Engine::take_preempted` returns them
    /// oldest-first, and calling `push_front` per request would reverse
    /// that, letting the youngest victim jump the line it just lost.
    pub fn push_front_all(&self, reqs: Vec<QueuedRequest>) {
        if reqs.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for r in reqs.into_iter().rev() {
            g.front.push_front(r);
        }
        self.cv.notify_all();
    }

    fn pop_locked(g: &mut Inner) -> Option<QueuedRequest> {
        if let Some(r) = g.front.pop_front() {
            return Some(r);
        }
        if g.lane.is_empty() {
            return None;
        }
        let mut best = 0usize;
        for i in 1..g.lane.len() {
            let (a, b) = (&g.lane[i], &g.lane[best]);
            if a.deadline_s < b.deadline_s
                || (a.deadline_s == b.deadline_s && a.seq < b.seq)
            {
                best = i;
            }
        }
        Some(g.lane.remove(best).req)
    }

    /// Non-blocking pop (engine polls between iterations).
    pub fn try_pop(&self) -> Option<QueuedRequest> {
        Self::pop_locked(&mut self.inner.lock().unwrap())
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop_wait(&self) -> Option<QueuedRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = Self::pop_locked(&mut g) {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Remove a queued request by id (either lane) — the cancellation path
    /// for requests whose client disconnected before admission. Returns the
    /// request so the caller can release any tier state riding in `resume`.
    pub fn remove(&self, id: u64) -> Option<QueuedRequest> {
        let mut g = self.inner.lock().unwrap();
        if let Some(i) = g.front.iter().position(|r| r.id == id) {
            return g.front.remove(i);
        }
        if let Some(i) = g.lane.iter().position(|e| e.req.id == id) {
            return Some(g.lane.remove(i).req);
        }
        None
    }

    /// Block until the queue is non-empty, closed, or `timeout` elapses.
    /// Returns true when a request is available. This is the engine's idle
    /// wait: a condvar wakeup on push instead of a sleep-poll floor.
    pub fn wait_nonempty(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.front.is_empty() || !g.lane.is_empty() {
                return true;
            }
            if g.closed {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (ng, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if res.timed_out() {
                return !g.front.is_empty() || !g.lane.is_empty();
            }
        }
    }

    /// Wake every waiter without enqueuing anything — used by connection
    /// threads after flagging a cancellation so an idle engine sweeps it
    /// immediately instead of at the next wait timeout.
    pub fn nudge(&self) {
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.front.len() + g.lane.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> QueuedRequest {
        QueuedRequest {
            id,
            prompt: String::new(),
            template: String::new(),
            max_new: 8,
            class: SloClass::Standard,
            queued_at: Instant::now(),
            resume: None,
            span: crate::telemetry::SpanContext::default(),
        }
    }

    fn req_class(id: u64, class: SloClass) -> QueuedRequest {
        QueuedRequest {
            class,
            ..req(id)
        }
    }

    /// A minimal preemption snapshot carrying only accumulated queue wait.
    fn snapshot(queued_s: f64) -> Arc<PreemptedState> {
        Arc::new(PreemptedState {
            records: Vec::new(),
            pos: 0,
            next_token: 0,
            next_forced: false,
            template_cursor: 0,
            out_text: String::new(),
            hole_predictions: Vec::new(),
            produced: 0,
            finish: None,
            evictions: 0,
            live_curve: Vec::new(),
            queued_s,
            admitted_at: Instant::now(),
            first_token_at: None,
            preempted_at: Instant::now(),
            swapped: None,
            parked: Default::default(),
        })
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.push(req(2));
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn push_front_jumps_the_line() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.push(req(2));
        q.push_front(req(9)); // a held/preempted request goes first
        assert_eq!(q.try_pop().unwrap().id, 9);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
    }

    #[test]
    fn push_front_all_preserves_victim_order() {
        let q = RequestQueue::new();
        q.push(req(1));
        // two same-step preemption victims, oldest (7) first — they must
        // pop in exactly that order, ahead of the queued request
        q.push_front_all(vec![req(7), req(8)]);
        assert_eq!(q.try_pop().unwrap().id, 7);
        assert_eq!(q.try_pop().unwrap().id, 8);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
        // empty batch is a no-op
        q.push_front_all(Vec::new());
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn interactive_overtakes_batch_and_standard() {
        let q = RequestQueue::new();
        q.push(req_class(1, SloClass::Batch));
        q.push(req_class(2, SloClass::Standard));
        q.push(req_class(3, SloClass::Interactive));
        assert_eq!(q.try_pop().unwrap().id, 3);
        assert_eq!(q.try_pop().unwrap().id, 2);
        assert_eq!(q.try_pop().unwrap().id, 1);
    }

    #[test]
    fn aged_batch_request_beats_fresh_interactive() {
        // deadline = enqueue + target: a batch request that has waited past
        // the target gap has the earlier deadline — aging prevents
        // starvation under a steady interactive stream
        let q = RequestQueue::new();
        let mut old = req_class(1, SloClass::Batch);
        // checked: Instant is monotonic-from-boot and may not reach back 60s
        let Some(past) = Instant::now().checked_sub(Duration::from_secs(60)) else {
            return;
        };
        old.queued_at = past;
        q.push(old);
        q.push(req_class(2, SloClass::Interactive));
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
    }

    #[test]
    fn accumulated_queue_wait_counts_toward_deadline() {
        // a resume carries prior queue wait (PreemptedState::queued_s); the
        // effective enqueue time moves back by that much, so a previously
        // starved request is not reset to the back of its class
        let q = RequestQueue::new();
        let mut waited = req(1);
        waited.resume = Some(snapshot(3600.0));
        q.push(waited);
        q.push(req_class(2, SloClass::Interactive));
        assert_eq!(q.try_pop().unwrap().id, 1);
    }

    #[test]
    fn front_lane_outranks_every_class() {
        let q = RequestQueue::new();
        q.push(req_class(1, SloClass::Interactive));
        q.push_front(req_class(9, SloClass::Batch)); // declined re-queue
        assert_eq!(q.try_pop().unwrap().id, 9);
        assert_eq!(q.try_pop().unwrap().id, 1);
    }

    #[test]
    fn remove_plucks_from_either_lane() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.push(req(2));
        q.push_front(req(3));
        assert_eq!(q.remove(2).unwrap().id, 2);
        assert_eq!(q.remove(3).unwrap().id, 3);
        assert!(q.remove(99).is_none());
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn wait_nonempty_wakes_on_push_and_times_out_empty() {
        let q = Arc::new(RequestQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            q2.wait_nonempty(Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(10));
        q.push(req(1));
        assert!(h.join().unwrap(), "waiter must see the push");
        q.try_pop();
        assert!(!q.wait_nonempty(Duration::from_millis(5)));
    }

    #[test]
    fn close_unblocks_waiters() {
        let q = Arc::new(RequestQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn pop_wait_gets_pushed_item() {
        let q = Arc::new(RequestQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_wait().map(|r| r.id));
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.push(req(42));
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn drain_then_close() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.close();
        assert_eq!(q.pop_wait().unwrap().id, 1);
        assert!(q.pop_wait().is_none());
    }
}
