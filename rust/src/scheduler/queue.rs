//! Thread-safe FIFO admission queue shared between the server front-end and
//! the engine thread (std sync primitives; tokio is not in the offline set).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::PreemptedState;

#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub prompt: String,
    /// Forced-continuation template: after the prompt the engine feeds these
    /// chars as inputs; `?` marks holes the model must fill (answer digits).
    /// Empty ⇒ free-running generation.
    pub template: String,
    pub max_new: usize,
    /// When this request (re-)entered the queue. For a preempted request
    /// this is the re-queue time; the wait accumulated before earlier
    /// admissions travels inside `resume` (`PreemptedState::queued_s`), so
    /// wait-latency metrics always cover the full queued time.
    pub queued_at: Instant,
    /// Recompute-mode resume state for a preempted request (None for fresh
    /// submissions). Rides the queue round trip back into `Engine::submit`;
    /// `Arc` keeps the per-admission-attempt clone a refcount bump.
    pub resume: Option<Arc<PreemptedState>>,
}

#[derive(Default)]
struct Inner {
    q: VecDeque<QueuedRequest>,
    closed: bool,
}

/// MPSC-ish blocking queue with close semantics.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Default for RequestQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, req: QueuedRequest) {
        let mut g = self.inner.lock().unwrap();
        g.q.push_back(req);
        self.cv.notify_one();
    }

    /// Put a request at the *front* of the queue — used to hand back a
    /// request the engine declined under pool pressure, or one whose row was
    /// preempted, so it is first in line once blocks free up.
    pub fn push_front(&self, req: QueuedRequest) {
        let mut g = self.inner.lock().unwrap();
        g.q.push_front(req);
        self.cv.notify_one();
    }

    /// Put several requests at the front of the queue *preserving slice
    /// order*: `reqs[0]` pops first. This is the re-queue path for
    /// same-step preemption victims — `Engine::take_preempted` returns them
    /// oldest-first, and calling `push_front` per request would reverse
    /// that, letting the youngest victim jump the line it just lost.
    pub fn push_front_all(&self, reqs: Vec<QueuedRequest>) {
        if reqs.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for r in reqs.into_iter().rev() {
            g.q.push_front(r);
        }
        self.cv.notify_all();
    }

    /// Non-blocking pop (engine polls between iterations).
    pub fn try_pop(&self) -> Option<QueuedRequest> {
        self.inner.lock().unwrap().q.pop_front()
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop_wait(&self) -> Option<QueuedRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> QueuedRequest {
        QueuedRequest {
            id,
            prompt: String::new(),
            template: String::new(),
            max_new: 8,
            queued_at: Instant::now(),
            resume: None,
        }
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.push(req(2));
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn push_front_jumps_the_line() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.push(req(2));
        q.push_front(req(9)); // a held/preempted request goes first
        assert_eq!(q.try_pop().unwrap().id, 9);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
    }

    #[test]
    fn push_front_all_preserves_victim_order() {
        let q = RequestQueue::new();
        q.push(req(1));
        // two same-step preemption victims, oldest (7) first — they must
        // pop in exactly that order, ahead of the queued request
        q.push_front_all(vec![req(7), req(8)]);
        assert_eq!(q.try_pop().unwrap().id, 7);
        assert_eq!(q.try_pop().unwrap().id, 8);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
        // empty batch is a no-op
        q.push_front_all(Vec::new());
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn close_unblocks_waiters() {
        let q = Arc::new(RequestQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn pop_wait_gets_pushed_item() {
        let q = Arc::new(RequestQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_wait().map(|r| r.id));
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.push(req(42));
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn drain_then_close() {
        let q = RequestQueue::new();
        q.push(req(1));
        q.close();
        assert_eq!(q.pop_wait().unwrap().id, 1);
        assert!(q.pop_wait().is_none());
    }
}
