//! Fleet routing: place each request on the engine replica where its KV
//! sharing can actually pay off, fall back to pool pressure elsewhere.
//!
//! LazyEviction's prefix reuse (and Token Importance Recurrence generally)
//! only helps where the donor blocks *live* — a prompt whose header sits in
//! replica 2's `PrefixCache` is a guaranteed prefill skip there and a cold
//! prefill anywhere else. The router therefore keys placement on the same
//! block-boundary FNV-1a hashes the cache itself stores
//! ([`crate::kvpool::boundary_hashes`]): each replica periodically exports
//! the sorted hash set of its cache entries ([`crate::kvpool::PrefixCache::
//! digest`]), and [`Router::choose`] probes a request's *header hashes*
//! (every whole-block prefix of its prompt, longest first) against those
//! digests. Hashes are a placement hint only — the target cache still
//! token-verifies on lookup, so a collision can at worst forfeit a hit,
//! never splice wrong bytes.
//!
//! Two affinity sources, checked in order:
//!
//! 1. **sticky map** — the router remembers where it last *sent* each
//!    longest header hash. Fresher than any digest (it records the latest
//!    actual decision): it covers the publish race — the first request of
//!    a burst seeds a replica's cache, but that replica's digest is only
//!    re-exported on its next telemetry tick, so without stickiness the
//!    rest of the burst would scatter — and keeps a rebalanced header on
//!    its new home;
//! 2. **digest match** — some replica's published digest contains one of
//!    the request's header hashes (longest match wins, ties broken by the
//!    pressure ordering below).
//!
//! Everything else (no header match, `--routing pressure`, affinity target
//! starved) falls back to **pressure balancing** over the replica gauges
//! the telemetry layer already exports: most free blocks first, then fewest
//! parked tier bytes, then shortest queue+active load, then a *seeded*
//! deterministic hash tie-break — so equal-pressure placement is a pure
//! function of (seed, request id) and tests can pin it.
//!
//! An affinity target that has fallen at-or-under its free-block floor
//! (`pressure_floor`, wired to the pool's low watermark) is *rebalanced*:
//! a cold prefill elsewhere beats queueing behind a preemption storm, and
//! `router_rebalances_total` counts how often that trade was taken.

use std::collections::HashMap;

use crate::kvpool::boundary_hashes;

/// Routing policy selected by `--routing affinity|pressure|rr`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    /// Prefix-affinity first, pressure fallback (the default).
    Affinity,
    /// Pure pressure balancing (ignores digests).
    Pressure,
    /// Round-robin over live replicas (baseline / bench control).
    RoundRobin,
}

impl Routing {
    pub fn parse(s: &str) -> Option<Routing> {
        match s {
            "affinity" => Some(Routing::Affinity),
            "pressure" => Some(Routing::Pressure),
            "rr" | "round-robin" => Some(Routing::RoundRobin),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Routing::Affinity => "affinity",
            Routing::Pressure => "pressure",
            Routing::RoundRobin => "rr",
        }
    }
}

/// One replica's routing-relevant state, sampled from its published
/// `ReplicaStatus` atomics + digest. A dead replica (`alive == false`)
/// is never chosen.
#[derive(Clone, Debug, Default)]
pub struct ReplicaView {
    pub alive: bool,
    pub free_blocks: usize,
    pub total_blocks: usize,
    pub parked_bytes: usize,
    pub queue_len: usize,
    pub active: usize,
    /// Free-block level at or under which this replica counts as starved
    /// (wired to the pool's low watermark).
    pub pressure_floor: usize,
    /// Sorted whole-block header hashes of the replica's prefix cache.
    pub digest: Vec<u64>,
}

impl ReplicaView {
    fn starved(&self) -> bool {
        self.free_blocks <= self.pressure_floor
    }

    fn has_hash(&self, h: u64) -> bool {
        self.digest.binary_search(&h).is_ok()
    }
}

/// Why `choose` picked the replica it picked (drives the router counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteReason {
    /// Header hash matched a replica digest or the sticky map.
    Affinity,
    /// No affinity signal (or policy `pressure`): gauge-balanced pick.
    Pressure,
    /// Round-robin policy.
    RoundRobin,
    /// Affinity target was starved; re-placed by pressure.
    Rebalanced,
}

impl RouteReason {
    /// Stable label used in span notes and the flag/metric surface
    /// (matches `Routing::as_str` where the variants overlap).
    pub fn as_str(self) -> &'static str {
        match self {
            RouteReason::Affinity => "affinity",
            RouteReason::Pressure => "pressure",
            RouteReason::RoundRobin => "rr",
            RouteReason::Rebalanced => "rebalanced",
        }
    }
}

/// A placement decision: target replica + how it was reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub replica: usize,
    pub reason: RouteReason,
}

/// Monotone counters the router publishes as
/// `lazyeviction_router_{routed_affinity,routed_pressure,routed_rr,
/// rebalances}_total`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RouterCounters {
    pub routed_affinity: u64,
    pub routed_pressure: u64,
    pub routed_rr: u64,
    pub rebalances: u64,
}

/// Every whole-block header hash of `ids`, **longest prefix first**, for
/// probing against replica digests. This is [`boundary_hashes`] minus its
/// k = 0 snapshot: the hash of the empty prefix is a constant
/// (`FNV_OFFSET`) that every prompt shares, so including it would make
/// every request "match" any replica whose cache is non-empty.
pub fn header_hashes(ids: &[u32], block_size: usize) -> Vec<u64> {
    let mut out = boundary_hashes(ids, block_size);
    out.remove(0);
    out.reverse();
    out
}

/// Upper bound on sticky-map entries before it is wholesale cleared.
/// Stickiness only matters within a burst (until the target replica's next
/// digest publish), so losing the map costs at most one pressure-routed
/// request per active header — bounded memory matters more.
const STICKY_CAP: usize = 4096;

/// The fleet placement engine. One per server; callers sample per-replica
/// [`ReplicaView`]s and ask for a [`Decision`].
#[derive(Debug)]
pub struct Router {
    policy: Routing,
    seed: u64,
    /// longest header hash → replica we last sent it to.
    sticky: HashMap<u64, usize>,
    rr_next: usize,
    pub counters: RouterCounters,
}

impl Router {
    pub fn new(policy: Routing, seed: u64) -> Router {
        Router {
            policy,
            seed,
            sticky: HashMap::new(),
            rr_next: 0,
            counters: RouterCounters::default(),
        }
    }

    pub fn policy(&self) -> Routing {
        self.policy
    }

    /// Pick a replica for a request with header hashes `hashes` (longest
    /// first, from [`header_hashes`]) and id `req_id` (tie-break input).
    /// Returns `None` iff no replica is alive.
    pub fn choose(&mut self, hashes: &[u64], req_id: u64, views: &[ReplicaView]) -> Option<Decision> {
        if !views.iter().any(|v| v.alive) {
            return None;
        }
        match self.policy {
            Routing::RoundRobin => {
                let n = views.len();
                for _ in 0..n {
                    let r = self.rr_next % n;
                    self.rr_next = self.rr_next.wrapping_add(1);
                    if views[r].alive {
                        self.counters.routed_rr += 1;
                        return Some(Decision {
                            replica: r,
                            reason: RouteReason::RoundRobin,
                        });
                    }
                }
                None
            }
            Routing::Pressure => Some(self.by_pressure(hashes, req_id, views)),
            Routing::Affinity => Some(self.by_affinity(hashes, req_id, views)),
        }
    }

    fn by_affinity(&mut self, hashes: &[u64], req_id: u64, views: &[ReplicaView]) -> Decision {
        // 1. sticky map: where we last *sent* this exact header. Checked
        //    before the digests because it is always fresher — it records
        //    the latest actual decision, while a digest is only as recent
        //    as its replica's last publish. This both covers the publish
        //    race (burst follows its first request) and keeps a rebalanced
        //    header on its *new* home even though the old home's digest
        //    still lists it.
        let mut home: Option<(u64, usize)> = None;
        if let Some(&h) = hashes.first() {
            if let Some(&r) = self.sticky.get(&h) {
                if views.get(r).map(|v| v.alive).unwrap_or(false) {
                    home = Some((h, r));
                }
            }
        }
        // 2. longest header hash present in a live replica's digest.
        if home.is_none() {
            'probe: for &h in hashes {
                let mut best: Option<usize> = None;
                for (r, v) in views.iter().enumerate() {
                    if v.alive && v.has_hash(h) && self.better_pressure(views, r, best, req_id) {
                        best = Some(r);
                    }
                }
                if let Some(r) = best {
                    home = Some((h, r));
                    break 'probe;
                }
            }
        }
        if let Some((h, r)) = home {
            if views[r].starved() {
                // The home replica is under its free-block floor: a cold
                // prefill on a healthy replica beats queueing behind a
                // preemption storm. Only rebalance if somewhere better
                // actually exists.
                let alt = self.pressure_pick(req_id, views);
                if alt != r && !views[alt].starved() {
                    self.counters.rebalances += 1;
                    self.counters.routed_pressure += 1;
                    self.remember(h, alt);
                    return Decision {
                        replica: alt,
                        reason: RouteReason::Rebalanced,
                    };
                }
            }
            self.counters.routed_affinity += 1;
            self.remember(h, r);
            return Decision {
                replica: r,
                reason: RouteReason::Affinity,
            };
        }
        let d = self.by_pressure(hashes, req_id, views);
        if let Some(&h) = hashes.first() {
            self.remember(h, d.replica);
        }
        d
    }

    fn by_pressure(&mut self, _hashes: &[u64], req_id: u64, views: &[ReplicaView]) -> Decision {
        let r = self.pressure_pick(req_id, views);
        self.counters.routed_pressure += 1;
        Decision {
            replica: r,
            reason: RouteReason::Pressure,
        }
    }

    /// Gauge-balanced pick over live replicas: max free blocks, then min
    /// parked bytes, then min (queue + active), then seeded hash of
    /// (seed, req_id, replica) — fully deterministic given the seed.
    fn pressure_pick(&self, req_id: u64, views: &[ReplicaView]) -> usize {
        let mut best: Option<usize> = None;
        for (r, v) in views.iter().enumerate() {
            if v.alive && self.better_pressure(views, r, best, req_id) {
                best = Some(r);
            }
        }
        best.expect("choose() pre-checked a live replica exists")
    }

    /// Is replica `cand` a strictly better pressure pick than `cur`?
    fn better_pressure(&self, views: &[ReplicaView], cand: usize, cur: Option<usize>, req_id: u64) -> bool {
        let cur = match cur {
            None => return true,
            Some(c) => c,
        };
        let key = |x: &ReplicaView| {
            (
                std::cmp::Reverse(x.free_blocks),
                x.parked_bytes,
                x.queue_len + x.active,
            )
        };
        match key(&views[cand]).cmp(&key(&views[cur])) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            // seeded tie-break: smaller mixed hash wins; replica index is
            // mixed in so different replicas get different draws.
            std::cmp::Ordering::Equal => self.tie_hash(req_id, cand) < self.tie_hash(req_id, cur),
        }
    }

    fn tie_hash(&self, req_id: u64, replica: usize) -> u64 {
        // splitmix64 over (seed ^ req_id ^ replica-salt): cheap, stateless,
        // and stable across calls — equal-pressure choice is reproducible.
        let mut z = self
            .seed
            .wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(req_id.wrapping_add(1)))
            .wrapping_add((replica as u64).wrapping_mul(0xbf58_476d_1ce4_e5b9));
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn remember(&mut self, hash: u64, replica: usize) {
        if self.sticky.len() >= STICKY_CAP {
            self.sticky.clear();
        }
        self.sticky.insert(hash, replica);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::prefix_hash;

    fn view(free: usize) -> ReplicaView {
        ReplicaView {
            alive: true,
            free_blocks: free,
            total_blocks: 64,
            parked_bytes: 0,
            queue_len: 0,
            active: 0,
            pressure_floor: 4,
            digest: Vec::new(),
        }
    }

    // ---- satellite: routing-hash property tests -------------------------

    /// Extending a prompt by whole blocks never changes the hashes of the
    /// blocks it already had — the property affinity routing depends on:
    /// a follow-up request with a longer body still probes the same header
    /// keys its predecessor seeded.
    #[test]
    fn header_hash_stable_under_block_aligned_extension() {
        let bs = 4usize;
        let base: Vec<u32> = (0..12).collect(); // 3 whole blocks
        let mut extended = base.clone();
        extended.extend(100..108); // +2 whole blocks
        let hb = header_hashes(&base, bs);
        let he = header_hashes(&extended, bs);
        assert_eq!(hb.len(), 3);
        assert_eq!(he.len(), 5);
        // longest-first ordering: base's hashes are the *tail* of extended's
        assert_eq!(&he[2..], &hb[..], "shared whole-block hashes identical");
        // and each is exactly the cache's own entry key for that prefix
        assert_eq!(hb[0], prefix_hash(&base));
        assert_eq!(he[0], prefix_hash(&extended));
    }

    /// A sub-block tail changes nothing: header hashes only exist at block
    /// boundaries, so ragged suffixes can't perturb placement.
    #[test]
    fn header_hash_ignores_ragged_tail() {
        let bs = 4usize;
        let base: Vec<u32> = (0..8).collect();
        let mut ragged = base.clone();
        ragged.extend([7, 7, 7]); // 3 tokens: not a whole block
        assert_eq!(header_hashes(&base, bs), header_hashes(&ragged, bs));
    }

    /// The empty-prefix snapshot must be excluded — it is a constant every
    /// prompt shares, so keeping it would make everything "match".
    #[test]
    fn header_hashes_exclude_empty_prefix() {
        let ids: Vec<u32> = (0..4).collect();
        let hs = header_hashes(&ids, 4);
        assert_eq!(hs, vec![prefix_hash(&ids)]);
        assert!(header_hashes(&ids[..3], 4).is_empty(), "sub-block prompt has no header keys");
    }

    /// Equal pressure everywhere → the pick is a pure function of
    /// (seed, request id): same across router instances with the same
    /// seed, and at least one req_id maps to a different replica so the
    /// tie-break actually spreads load.
    #[test]
    fn equal_pressure_tie_break_is_seeded_and_deterministic() {
        let views = vec![view(32), view(32), view(32)];
        let picks: Vec<usize> = (0..64)
            .map(|id| {
                let mut a = Router::new(Routing::Pressure, 7);
                let mut b = Router::new(Routing::Pressure, 7);
                let pa = a.choose(&[], id, &views).unwrap().replica;
                let pb = b.choose(&[], id, &views).unwrap().replica;
                assert_eq!(pa, pb, "same seed, same id → same replica");
                pa
            })
            .collect();
        assert!(
            picks.iter().any(|&p| p != picks[0]),
            "tie-break must spread across replicas, got {picks:?}"
        );
        // a different seed is allowed to (and here does) permute some pick
        let mut other = Router::new(Routing::Pressure, 8);
        let differs = (0..64).any(|id| {
            let p = other.choose(&[], id, &views).unwrap().replica;
            p != picks[id as usize]
        });
        assert!(differs, "seed must influence the tie-break");
    }

    // ---- affinity / pressure / rr behavior ------------------------------

    #[test]
    fn digest_match_routes_home_longest_first() {
        let ids: Vec<u32> = (0..8).collect();
        let hs = header_hashes(&ids, 4); // [hash(8 tok), hash(4 tok)]
        let mut views = vec![view(32), view(8), view(32)];
        views[1].digest = vec![hs[1]]; // replica 1 knows the short header
        views[2].digest = vec![hs[0]]; // replica 2 knows the full prompt
        views[1].digest.sort_unstable();
        views[2].digest.sort_unstable();
        let mut r = Router::new(Routing::Affinity, 7);
        let d = r.choose(&hs, 1, &views).unwrap();
        assert_eq!(d.replica, 2, "longest match wins even at lower free");
        assert_eq!(d.reason, RouteReason::Affinity);
        assert_eq!(r.counters.routed_affinity, 1);
        assert_eq!(r.counters.routed_pressure, 0);
    }

    /// The sticky map covers the digest-publish race: once a header has
    /// been *sent* somewhere, follow-ups go there too even though the
    /// replica's digest hasn't been re-exported yet.
    #[test]
    fn sticky_map_holds_a_burst_together_before_digest_publish() {
        let ids: Vec<u32> = (0..8).collect();
        let hs = header_hashes(&ids, 4);
        // all digests empty: first request is pressure-routed
        let views = vec![view(30), view(32), view(31)];
        let mut r = Router::new(Routing::Affinity, 7);
        let first = r.choose(&hs, 1, &views).unwrap();
        assert_eq!(first.replica, 1, "most free blocks");
        assert_eq!(first.reason, RouteReason::Pressure);
        // second identical prompt: still no digest anywhere, but sticky
        let second = r.choose(&hs, 2, &views).unwrap();
        assert_eq!(second.replica, 1);
        assert_eq!(second.reason, RouteReason::Affinity);
        assert_eq!(r.counters.routed_affinity, 1);
        assert_eq!(r.counters.routed_pressure, 1);
    }

    #[test]
    fn starved_home_rebalances_to_healthy_replica() {
        let ids: Vec<u32> = (0..4).collect();
        let hs = header_hashes(&ids, 4);
        let mut views = vec![view(2), view(32), view(16)];
        views[0].digest = vec![hs[0]]; // home, but free=2 <= floor=4
        let mut r = Router::new(Routing::Affinity, 7);
        let d = r.choose(&hs, 1, &views).unwrap();
        assert_eq!(d.replica, 1, "most free healthy replica");
        assert_eq!(d.reason, RouteReason::Rebalanced);
        assert_eq!(r.counters.rebalances, 1);
        // and the sticky map now points at the new home: the burst follows
        let follow = r.choose(&hs, 2, &views).unwrap();
        assert_eq!(follow.replica, 1);
        assert_eq!(follow.reason, RouteReason::Affinity);
    }

    /// If *everywhere* is starved there is nothing to gain by moving —
    /// stay home and keep the prefix hit.
    #[test]
    fn no_rebalance_when_all_replicas_starved() {
        let ids: Vec<u32> = (0..4).collect();
        let hs = header_hashes(&ids, 4);
        let mut views = vec![view(2), view(3)];
        views[0].digest = vec![hs[0]];
        let mut r = Router::new(Routing::Affinity, 7);
        let d = r.choose(&hs, 1, &views).unwrap();
        assert_eq!(d.replica, 0);
        assert_eq!(d.reason, RouteReason::Affinity);
        assert_eq!(r.counters.rebalances, 0);
    }

    #[test]
    fn pressure_orders_free_then_parked_then_load() {
        let mut views = vec![view(16), view(16), view(16)];
        views[0].parked_bytes = 4096;
        views[1].parked_bytes = 4096;
        views[1].queue_len = 3;
        let mut r = Router::new(Routing::Pressure, 7);
        assert_eq!(r.choose(&[], 1, &views).unwrap().replica, 2);
        views[2].free_blocks = 1; // now worst on the primary key
        assert_eq!(r.choose(&[], 1, &views).unwrap().replica, 0);
        assert_eq!(r.counters.routed_pressure, 2);
    }

    #[test]
    fn round_robin_cycles_and_skips_dead() {
        let mut views = vec![view(32), view(32), view(32)];
        views[1].alive = false;
        let mut r = Router::new(Routing::RoundRobin, 7);
        let picks: Vec<usize> = (0..4)
            .map(|id| r.choose(&[], id, &views).unwrap().replica)
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "dead replica skipped, order cycles");
        assert_eq!(r.counters.routed_rr, 4);
    }

    #[test]
    fn dead_replicas_never_chosen_no_alive_is_none() {
        let ids: Vec<u32> = (0..4).collect();
        let hs = header_hashes(&ids, 4);
        let mut views = vec![view(32), view(2)];
        views[0].digest = vec![hs[0]];
        views[0].alive = false;
        let mut r = Router::new(Routing::Affinity, 7);
        // digest match on a dead replica is ignored → pressure pick
        let d = r.choose(&hs, 1, &views).unwrap();
        assert_eq!(d.replica, 1);
        views[1].alive = false;
        assert!(r.choose(&hs, 2, &views).is_none(), "no live replica → None");
        let mut rr = Router::new(Routing::RoundRobin, 7);
        assert!(rr.choose(&[], 1, &views).is_none());
    }

    #[test]
    fn sticky_map_is_capacity_bounded() {
        let views = vec![view(32), view(32)];
        let mut r = Router::new(Routing::Affinity, 7);
        for i in 0..(STICKY_CAP as u64 + 10) {
            let h = [0xdead_0000u64 + i];
            r.choose(&h, i, &views);
        }
        assert!(r.sticky.len() <= STICKY_CAP);
    }

    #[test]
    fn routing_parse_round_trips() {
        for (s, v) in [
            ("affinity", Routing::Affinity),
            ("pressure", Routing::Pressure),
            ("rr", Routing::RoundRobin),
        ] {
            assert_eq!(Routing::parse(s), Some(v));
            assert_eq!(v.as_str(), s);
        }
        assert_eq!(Routing::parse("round-robin"), Some(Routing::RoundRobin));
        assert_eq!(Routing::parse("nope"), None);
    }
}
