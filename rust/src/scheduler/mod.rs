//! Request scheduling: deadline-ordered admission queue + continuous
//! batcher + pool-pressure admission control.
//!
//! The engine has a fixed number of batch rows (the compiled executable's
//! batch dimension). The batcher admits queued requests into free rows at
//! iteration granularity (Orca-style continuous batching): finished rows
//! free immediately and the next queued request is prefilled into the slot
//! while other rows keep decoding.
//!
//! With a shared KV block pool, free rows are no longer sufficient: the
//! `admission::AdmissionController` holds the queue while free blocks sit
//! under the pool's low watermark (hysteresis up to the high watermark).
//! Requests the engine preempts come back oldest-victim-first, each
//! carrying its decode-state snapshot (`QueuedRequest::resume`), and
//! re-enter via `RequestQueue::push_front_all` — one batch insertion that
//! preserves that order, where a per-request `push_front` loop would
//! reverse same-step victims. Their re-admission *resumes* generation
//! (recompute mode) rather than restarting it.
//!
//! Fresh arrivals are no longer plain FIFO: each request carries an
//! [`queue::SloClass`] and the queue pops the earliest *deadline* first
//! (enqueue time minus accumulated wait, plus the class's TTFT target) —
//! TTFT-priority admission with aging built in. Declined/preempted
//! re-queues bypass deadline ordering entirely (front lane).
//!
//! Above the per-replica queues sits the fleet layer ([`routing`]): a
//! [`routing::Router`] places each incoming request on one of N engine
//! replicas by prefix affinity (block-boundary header hashes probed
//! against per-replica `PrefixCache` digests) with pool-pressure
//! balancing as the fallback. Each replica then runs exactly the
//! single-engine admission/preemption machinery above, over its own
//! queue — preemption re-queues in particular stay on their home
//! replica's front lane, oldest-victim-first.

pub mod admission;
pub mod preempt;
pub mod queue;
pub mod routing;

pub use admission::{derive_watermarks, AdmissionController};
pub use queue::{QueuedRequest, RequestQueue, SloClass};
pub use routing::{header_hashes, Decision, ReplicaView, RouteReason, Router, RouterCounters, Routing};

/// Iteration-level admission decisions for a fixed-row engine.
#[derive(Debug)]
pub struct Batcher {
    rows: Vec<Option<u64>>, // request id per row
}

impl Batcher {
    pub fn new(n_rows: usize) -> Batcher {
        Batcher {
            rows: vec![None; n_rows],
        }
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn free_rows(&self) -> Vec<usize> {
        self.rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn occupancy(&self) -> usize {
        self.rows.iter().filter(|r| r.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.occupancy() == 0
    }

    /// Assign a request to a free row; returns the row index.
    pub fn admit(&mut self, req_id: u64) -> Option<usize> {
        let row = self.rows.iter().position(|r| r.is_none())?;
        self.rows[row] = Some(req_id);
        Some(row)
    }

    pub fn release(&mut self, row: usize) -> Option<u64> {
        self.rows.get_mut(row).and_then(|r| r.take())
    }

    pub fn request_at(&self, row: usize) -> Option<u64> {
        self.rows.get(row).copied().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_fills_lowest_free_row() {
        let mut b = Batcher::new(3);
        assert_eq!(b.admit(10), Some(0));
        assert_eq!(b.admit(11), Some(1));
        b.release(0);
        assert_eq!(b.admit(12), Some(0));
        assert_eq!(b.occupancy(), 2);
    }

    #[test]
    fn admit_full_returns_none() {
        let mut b = Batcher::new(1);
        assert_eq!(b.admit(1), Some(0));
        assert_eq!(b.admit(2), None);
    }

    #[test]
    fn release_returns_request() {
        let mut b = Batcher::new(2);
        b.admit(7);
        assert_eq!(b.release(0), Some(7));
        assert_eq!(b.release(0), None);
        assert!(b.is_idle());
    }

    #[test]
    fn continuous_batching_interleave() {
        // rows free and refill independently — the continuous-batching core
        let mut b = Batcher::new(2);
        b.admit(1);
        b.admit(2);
        b.release(1); // request 2 finished early
        assert_eq!(b.admit(3), Some(1)); // request 3 joins while 1 decodes
        assert_eq!(b.request_at(0), Some(1));
        assert_eq!(b.request_at(1), Some(3));
    }
}
