//! Pressure-driven admission control over the shared KV block pool.
//!
//! A bare `free < needed` check admits right up to the cliff edge and then
//! thrashes: every eviction pass frees a block, one request is admitted, the
//! pool is instantly dry again and the new row gets preempted. The
//! controller adds hysteresis around the pool's two watermarks instead:
//! once free blocks dip under `low_watermark`, admissions *hold* until the
//! pool recovers to `high_watermark` — leaving the freed blocks to the rows
//! already decoding (who finish and release more), rather than feeding an
//! admission/preemption cycle.
//!
//! ## Invariants
//!
//! * **Exact boundary semantics** — hold while `free < low`, resume at
//!   `free >= high`; `free == low` stays open and `free == high` reopens.
//!   `low == high` degenerates to a plain threshold latch. (Regression
//!   tests pin all four boundaries.)
//! * **Level-triggered, never edge-triggered** — the latch reacts to the
//!   *current* free count only, never to deltas. This matters with prefix
//!   sharing: releasing a shared block leaves `free` flat, and a
//!   copy-on-write burst can drop it several blocks in one step; a
//!   direction-sensitive latch would mis-handle both.
//! * **One controller per engine loop** — state is a single bool; the serve
//!   loop evaluates it once per iteration against a fresh
//!   [`PoolPressure`] snapshot. There is no cross-thread sharing.
//!
//! ## Failure modes
//!
//! The latch can wedge closed when *nothing is decoding*: no row will ever
//! finish and free blocks, so if stale prefix-cache pins hold `free` below
//! `high`, the queue would hang forever. The serve loop owns the escape
//! valve (`Engine::shed_prefix_to_high_watermark`) — the controller itself
//! deliberately knows nothing about where blocks are pinned.

use crate::kvpool::PoolPressure;
use crate::util::stats::percentile;

/// Derive (low, high) watermarks from an observed per-row live-set
/// distribution (`--auto-watermarks`; replay already measures per-policy
/// live curves). The rule:
///
/// * `low` = the *growth headroom* between a typical row and a near-worst
///   row, `blocks(p95) − blocks(p50)` — once free blocks dip under that,
///   the rows already decoding plausibly need every remaining block to
///   reach their own p95, so admitting more would only buy preemptions;
/// * `high` = `blocks(p95)` — reopen only once a whole near-worst row fits,
///   so a reopened latch does not immediately slam shut again.
///
/// Both clamp to `[1, n_blocks]` with `low <= high` (the `PoolConfig`
/// validation contract). Empty samples fall back to a minimal (1, 2) band.
pub fn derive_watermarks(
    live_samples: &[usize],
    block_size: usize,
    n_blocks: usize,
) -> (usize, usize) {
    let bs = block_size.max(1);
    let blocks_for = |tokens: f64| -> usize {
        let t = tokens.max(0.0).ceil() as usize;
        (t + bs - 1) / bs
    };
    if live_samples.is_empty() || n_blocks == 0 {
        return (1.min(n_blocks), 2.min(n_blocks).max(1.min(n_blocks)));
    }
    let xs: Vec<f64> = live_samples.iter().map(|&x| x as f64).collect();
    let b50 = blocks_for(percentile(&xs, 0.50));
    let b95 = blocks_for(percentile(&xs, 0.95));
    let low = b95.saturating_sub(b50).max(1).min(n_blocks);
    let high = b95.clamp(low, n_blocks);
    (low, high)
}

/// Hysteresis latch between the pool's low/high watermarks.
#[derive(Debug, Default)]
pub struct AdmissionController {
    holding: bool,
    /// How many times the controller transitioned into the hold state.
    pub hold_transitions: u64,
}

impl AdmissionController {
    pub fn new() -> AdmissionController {
        AdmissionController::default()
    }

    /// Is the gate currently closed?
    pub fn is_holding(&self) -> bool {
        self.holding
    }

    /// Evaluate the gate against the current pool pressure. Returns true
    /// when new admissions may proceed this iteration.
    pub fn allow(&mut self, p: &PoolPressure) -> bool {
        if self.holding {
            if p.at_or_above_high() {
                self.holding = false;
            } else {
                return false;
            }
        } else if p.below_low() {
            self.holding = true;
            self.hold_transitions += 1;
            return false;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pressure(free: usize) -> PoolPressure {
        PoolPressure {
            free,
            total: 16,
            low_watermark: 3,
            high_watermark: 6,
        }
    }

    #[test]
    fn open_above_low() {
        let mut a = AdmissionController::new();
        assert!(a.allow(&pressure(10)));
        assert!(a.allow(&pressure(3))); // at the low mark: still open
        assert!(!a.is_holding());
    }

    #[test]
    fn holds_below_low_until_high() {
        let mut a = AdmissionController::new();
        assert!(!a.allow(&pressure(2))); // dips under low: close
        assert!(a.is_holding());
        // recovery below the high mark keeps the gate closed (hysteresis)
        assert!(!a.allow(&pressure(4)));
        assert!(!a.allow(&pressure(5)));
        // reaching the high mark reopens
        assert!(a.allow(&pressure(6)));
        assert!(!a.is_holding());
        assert_eq!(a.hold_transitions, 1);
    }

    #[test]
    fn reentry_counts_transitions() {
        let mut a = AdmissionController::new();
        assert!(!a.allow(&pressure(0)));
        assert!(a.allow(&pressure(16)));
        assert!(!a.allow(&pressure(1)));
        assert_eq!(a.hold_transitions, 2);
    }

    #[test]
    fn exact_boundary_semantics() {
        // The contract, bit-exact: hold strictly below low, resume at
        // exactly high. free == low must stay open, free == high must
        // reopen, free == high - 1 must not.
        let mut a = AdmissionController::new();
        assert!(a.allow(&pressure(3))); // == low: open
        assert!(!a.allow(&pressure(2))); // == low - 1: close
        assert!(!a.allow(&pressure(5))); // == high - 1: still closed
        assert!(a.allow(&pressure(6))); // == high: reopen
        assert!(a.allow(&pressure(3))); // == low again: still open
        assert_eq!(a.hold_transitions, 1);
    }

    #[test]
    fn equal_watermarks_degenerate_to_a_threshold() {
        // low == high is a plain threshold latch with no hysteresis band
        let p = |free: usize| PoolPressure {
            free,
            total: 16,
            low_watermark: 4,
            high_watermark: 4,
        };
        let mut a = AdmissionController::new();
        assert!(a.allow(&p(4)));
        assert!(!a.allow(&p(3)));
        assert!(a.allow(&p(4)), "free == low == high must reopen");
        assert_eq!(a.hold_transitions, 1);
    }

    #[test]
    fn non_monotonic_free_counts_resolve_by_level_not_direction() {
        // With prefix sharing, releasing blocks may not raise `free` (the
        // refs were shared) and CoW can drop it abruptly. The latch must
        // react to levels only, never to deltas: a flat free count while
        // holding stays held; a single-step jump across both marks reopens.
        let mut a = AdmissionController::new();
        assert!(!a.allow(&pressure(1)));
        // shared-block releases: free stays flat below high — still held
        for _ in 0..5 {
            assert!(!a.allow(&pressure(1)));
        }
        // one recovery step jumps from under low to over high: reopens
        assert!(a.allow(&pressure(10)));
        // and an abrupt CoW drop from over high to under low: closes again
        assert!(!a.allow(&pressure(0)));
        assert_eq!(a.hold_transitions, 2);
    }

    #[test]
    fn derive_watermarks_pins_the_percentile_rule() {
        // synthetic distribution: live sets uniform over 1..=100 tokens,
        // 16-token blocks, 64-block pool. p50 = 50.5 → ceil 51 → 4 blocks;
        // p95 = 95.05 → ceil 96 → 6 blocks. low = 6 − 4 = 2, high = 6.
        let samples: Vec<usize> = (1..=100).collect();
        assert_eq!(derive_watermarks(&samples, 16, 64), (2, 6));
        // a tight distribution (every row identical) degenerates to a
        // minimal one-block band at the row's own footprint
        let flat = vec![32usize; 50];
        assert_eq!(derive_watermarks(&flat, 16, 64), (1, 2));
        // p95 beyond the pool clamps to it, low stays <= high
        let huge = vec![10_000usize; 10];
        let (low, high) = derive_watermarks(&huge, 16, 8);
        assert!(low <= high && high <= 8);
        // empty samples fall back to a minimal band
        assert_eq!(derive_watermarks(&[], 16, 64), (1, 2));
        // the result always satisfies PoolConfig::validate
        for samples in [vec![1usize], vec![5, 9, 200], (1..=100).collect()] {
            let (low, high) = derive_watermarks(&samples, 4, 16);
            assert!(low <= high && high <= 16 && low >= 1, "{low}/{high}");
        }
    }

    #[test]
    fn zero_watermarks_never_hold() {
        let mut a = AdmissionController::new();
        let p = PoolPressure {
            free: 0,
            total: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        assert!(a.allow(&p)); // free < 0 is impossible: gate stays open
    }
}
