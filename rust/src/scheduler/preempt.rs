//! Per-row preemption cost model: recompute-resume vs swap-resume.
//!
//! A preempted row can come back two ways (`coordinator::PreemptMode`):
//!
//! * **recompute** — drop the blocks now (free), re-prefill the whole fed
//!   stream (prompt + generated, `fed_tokens` positions of model compute)
//!   at resume, then rewrite only the live keep-set's rows. Cost grows with
//!   *sequence length*, and a stream past the prefill bucket falls off a
//!   cliff (restart from the prompt).
//! * **swap** — copy the live set's K/V rows device→host now and host→device
//!   at resume (`2 × live_tokens` rows of interconnect traffic), no model
//!   compute, no bucket cliff. Cost grows with the *live set*, which lagged
//!   eviction pins near B + W regardless of length.
//!
//! Both costs are linear in token-rows, so the model compares token counts
//! with a traffic factor on the swap side: one re-prefilled token is taken
//! to cost about one moved token-row, and a swap moves every live row twice.
//! The crossover is therefore at `fed = 2 × live` — for a lazy policy
//! (live ≈ B + W) every row longer than ~2(B + W) fed tokens swaps cheaper,
//! and the advantage widens linearly from there. `sim::capacity` measures
//! the two models side by side and `benches/pool.rs` reports the crossover.

/// Rows of device↔host traffic per live token across a full swap round trip
/// (one copy out at preemption, one copy in at resume).
pub const SWAP_TRAFFIC_FACTOR: usize = 2;

/// Should this row be preempted in swap mode rather than recompute mode?
/// `live_tokens` is the row's current live set (blocks to move),
/// `fed_tokens` its fed-stream length (prompt + generated — the recompute
/// prefill size). Ties go to recompute: equal cost buys no bucket risk at
/// resume only when the stream still fits the bucket, and the engine's
/// recompute path already handles the oversize case by restarting.
pub fn swap_beats_recompute(live_tokens: usize, fed_tokens: usize) -> bool {
    SWAP_TRAFFIC_FACTOR * live_tokens < fed_tokens
}

/// The fed-stream length past which swap wins for a given live set — the
/// crossover `benches/pool.rs` reports.
pub fn crossover_fed_tokens(live_tokens: usize) -> usize {
    SWAP_TRAFFIC_FACTOR * live_tokens + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_boundary_is_exact() {
        // live 48 (B=40, W=8): fed 96 ties → recompute; fed 97 → swap
        assert!(!swap_beats_recompute(48, 96));
        assert!(swap_beats_recompute(48, 97));
        assert_eq!(crossover_fed_tokens(48), 97);
    }

    #[test]
    fn short_rows_recompute_long_rows_swap() {
        // early in a sequence the live set IS the stream: recompute wins
        assert!(!swap_beats_recompute(30, 30));
        // deep into a lazily-evicted sequence the stream dwarfs the live set
        assert!(swap_beats_recompute(48, 4096));
    }

    #[test]
    fn degenerate_sizes() {
        assert!(!swap_beats_recompute(0, 0));
        assert!(swap_beats_recompute(0, 1), "an empty live set is free to move");
    }
}
