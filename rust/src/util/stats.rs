//! Small statistics helpers: summaries, percentiles, histograms, CDFs.

/// Summary of a sample (latencies, scores, ...).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 0.50),
            p90: percentile_sorted(&v, 0.90),
            p99: percentile_sorted(&v, 0.99),
            max: v[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Empirical CDF evaluated at `x` (fraction of samples <= x).
pub fn ecdf(xs: &[f64], x: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().filter(|&&v| v <= x).count() as f64 / xs.len() as f64
}

/// The value at which the ECDF first reaches `q` — e.g. "80% of MRIs are
/// below this" drives the paper's W selection rule (§4, Fig. 3c).
pub fn quantile_of(xs: &[f64], q: f64) -> f64 {
    percentile(xs, q)
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets (+overflow).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub overflow: u64,
    pub underflow: u64,
    pub n: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            overflow: 0,
            underflow: 0,
            n: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.counts.len() as f64;
            let idx = (((x - self.lo) / w) as usize).min(self.counts.len() - 1);
            self.counts[idx] += 1;
        }
    }

    /// Bucket centers with normalized densities (sums to 1 incl. tails).
    pub fn normalized(&self) -> Vec<(f64, f64)> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                (
                    self.lo + w * (i as f64 + 0.5),
                    c as f64 / self.n.max(1) as f64,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn ecdf_monotone() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(ecdf(&v, 0.5), 0.0);
        assert!((ecdf(&v, 2.0) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(ecdf(&v, 9.0), 1.0);
    }

    #[test]
    fn quantile_of_matches_paper_rule() {
        // 80th percentile of MRI distribution drives W
        let mris: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let w = quantile_of(&mris, 0.8);
        assert!((w - 80.2).abs() < 0.5, "{w}");
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(99.0);
        assert_eq!(h.counts, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.n, 12);
    }

    #[test]
    fn histogram_normalized_sums() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for _ in 0..100 {
            h.add(0.3);
        }
        let total: f64 = h.normalized().iter().map(|(_, d)| d).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
