//! Self-contained substrate utilities (the offline crate set has only the
//! `xla` closure, so JSON, RNG, stats and CLI parsing are implemented here).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod wire;

/// In-house property-test driver: runs `f` over `n` seeded random cases and
/// reports the failing seed so a failure is replayable with a unit test.
pub fn property_test(name: &str, n: u64, mut f: impl FnMut(&mut rng::Rng)) {
    for case in 0..n {
        let seed = 0x5EED_0000 + case;
        let mut r = rng::Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut r)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at seed {seed:#x} (case {case})");
            std::panic::resume_unwind(e);
        }
    }
}
