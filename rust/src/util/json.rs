//! Minimal JSON parser/serializer (serde/serde_json are not in the offline
//! crate set). Supports the full JSON grammar; numbers are f64 (adequate for
//! manifest/config/results files — no u64 ids cross this boundary).
//!
//! The grammar lives in [`super::wire::Lexer`] (the zero-copy lexer the
//! streaming serve path uses directly); `Json::parse` is a tree-builder over
//! that lexer, so cold-path tree parsing and hot-path visitor parsing cannot
//! drift apart.

use std::collections::BTreeMap;
use std::fmt;

use super::wire::Lexer;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // ---- accessors --------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — config loading ergonomics.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            offset: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // typed convenience getters used by config/manifest loaders
    pub fn f64_at(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or_else(|| JsonError {
            msg: format!("'{key}' is not a number"),
            offset: 0,
        })
    }

    pub fn usize_at(&self, key: &str) -> Result<usize, JsonError> {
        Ok(self.f64_at(key)? as usize)
    }

    pub fn str_at(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError {
            msg: format!("'{key}' is not a string"),
            offset: 0,
        })
    }

    pub fn arr_at(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?.as_arr().ok_or_else(|| JsonError {
            msg: format!("'{key}' is not an array"),
            offset: 0,
        })
    }

    // ---- parse ------------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut lx = Lexer::new(s.as_bytes());
        lx.ws();
        let v = build_value(&mut lx)?;
        lx.ws();
        if !lx.at_end() {
            return Err(lx.error("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialize ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity literals; emitting them
                    // verbatim would corrupt the stream (e.g. avg_latency_ms
                    // is NaN before any token is produced)
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if indent.is_some() {
                        nl(out, indent, depth + 1);
                    }
                    x.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if indent.is_some() {
                        nl(out, indent, depth + 1);
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Tree-builder over the zero-copy lexer: decodes strings into owned
/// `String`s and collects containers — the cold-path counterpart of
/// `wire::parse_request`.
fn build_value(lx: &mut Lexer<'_>) -> Result<Json, JsonError> {
    match lx.peek().ok_or_else(|| lx.error("unexpected end"))? {
        b'n' => lx.lit("null").map(|_| Json::Null),
        b't' => lx.lit("true").map(|_| Json::Bool(true)),
        b'f' => lx.lit("false").map(|_| Json::Bool(false)),
        b'"' => Ok(Json::Str(lx.raw_str()?.unescape()?.into_owned())),
        b'-' | b'0'..=b'9' => lx.number().map(Json::Num),
        b'[' => {
            lx.eat(b'[')?;
            let mut v = Vec::new();
            lx.ws();
            if lx.peek() == Some(b']') {
                lx.eat(b']')?;
                return Ok(Json::Arr(v));
            }
            loop {
                lx.ws();
                v.push(build_value(lx)?);
                lx.ws();
                match lx.peek() {
                    Some(b',') => lx.eat(b',')?,
                    Some(b']') => {
                        lx.eat(b']')?;
                        return Ok(Json::Arr(v));
                    }
                    _ => return Err(lx.error("expected ',' or ']'")),
                }
            }
        }
        b'{' => {
            lx.eat(b'{')?;
            let mut m = BTreeMap::new();
            lx.ws();
            if lx.peek() == Some(b'}') {
                lx.eat(b'}')?;
                return Ok(Json::Obj(m));
            }
            loop {
                lx.ws();
                let k = lx.raw_str()?.unescape()?.into_owned();
                lx.ws();
                lx.eat(b':')?;
                lx.ws();
                m.insert(k, build_value(lx)?);
                lx.ws();
                match lx.peek() {
                    Some(b',') => lx.eat(b',')?,
                    Some(b'}') => {
                        lx.eat(b'}')?;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(lx.error("expected ',' or '}'")),
                }
            }
        }
        c => Err(lx.error(&format!("unexpected '{}'", c as char))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].str_at("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":true,"d":null},"e":-3}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::parse(r#"{"a":[1,2],"b":"x"}"#).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .set("name", "x")
            .set("n", 3usize)
            .set("xs", vec![1i64, 2]);
        assert_eq!(v.str_at("name").unwrap(), "x");
        assert_eq!(v.usize_at("n").unwrap(), 3);
    }

    #[test]
    fn typed_getter_errors() {
        let v = Json::parse(r#"{"a":"x"}"#).unwrap();
        assert!(v.f64_at("a").is_err());
        assert!(v.f64_at("missing").is_err());
    }

    #[test]
    fn non_finite_serializes_as_null() {
        let v = Json::obj()
            .set("nan", f64::NAN)
            .set("inf", f64::INFINITY)
            .set("ninf", f64::NEG_INFINITY)
            .set("ok", 1.5);
        let s = v.to_string();
        let back = Json::parse(&s).expect("non-finite floats must not corrupt the stream");
        assert_eq!(back.get("nan"), Some(&Json::Null));
        assert_eq!(back.get("inf"), Some(&Json::Null));
        assert_eq!(back.get("ninf"), Some(&Json::Null));
        assert_eq!(back.f64_at("ok").unwrap(), 1.5);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn escape_sequences_decode() {
        let v = Json::parse(r#""\"\\\/\b\f\n\r\tA😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\"\\/\u{8}\u{c}\n\r\tA😀");
    }

    #[test]
    fn truncated_input_rejected_at_every_prefix() {
        // every strict prefix of a document whose top-level value only
        // completes on the final byte must be a clean Err — including the
        // mid-surrogate-pair cuts that crashed the pre-lexer parser
        for doc in [
            r#"{"a":[1,2.5,"x\"y"],"b":{"c":true,"d":null}}"#,
            r#""pre 😀 post""#,
            r#"[true,"A",-1.5e-2]"#,
        ] {
            for cut in 0..doc.len() {
                if !doc.is_char_boundary(cut) {
                    continue;
                }
                assert!(
                    Json::parse(&doc[..cut]).is_err(),
                    "prefix {:?} must be rejected",
                    &doc[..cut]
                );
            }
            assert!(Json::parse(doc).is_ok());
        }
    }

    #[test]
    fn random_trees_roundtrip() {
        // property check of the rebuilt parse path against the serializer:
        // any tree we can emit must parse back identically
        fn gen(r: &mut crate::util::rng::Rng, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.chance(0.5)),
                2 => Json::Num((r.below(2000) as f64 - 1000.0) / 8.0),
                3 => {
                    let mut s = String::new();
                    for _ in 0..r.below(12) {
                        s.push(match r.below(6) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => '😀',
                            _ => char::from_u32(0x20 + r.below(0x5e) as u32).unwrap(),
                        });
                    }
                    Json::Str(s)
                }
                4 => Json::Arr((0..r.below(4)).map(|_| gen(r, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..r.below(4))
                        .map(|k| (format!("k{k}"), gen(r, depth - 1)))
                        .collect(),
                ),
            }
        }
        crate::util::property_test("json_roundtrip", 128, |r| {
            let v = gen(r, 3);
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(back, v);
            assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
        });
    }
}
