//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch is done by the caller on `positional[0]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    match iter.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(rest.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(rest.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("serve --port 8088 --budget=512 --verbose --name x");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 8088);
        assert_eq!(a.usize_or("budget", 0), 512);
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.str_or("name", ""), "x");
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse("--a --b v");
        assert!(a.bool_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert!(!a.bool_flag("missing"));
    }

    #[test]
    fn positional_order() {
        let a = parse("one two --k v three");
        assert_eq!(a.positional, vec!["one", "two", "three"]);
    }

    #[test]
    fn negative_number_as_value() {
        // `--x -3` : "-3" does not start with --, so it is a value
        let a = parse("--x -3");
        assert_eq!(a.f64_or("x", 0.0), -3.0);
    }
}
