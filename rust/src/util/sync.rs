//! Synchronization helpers shared by the serve loop, the engine actors and
//! the telemetry listener.
//!
//! The one that matters: [`lock_unpoisoned`]. The serving threads follow a
//! deterministic-failure-routing contract (ARCHITECTURE.md §The event-driven
//! serve loop): a panicked worker must never cascade into killing the
//! listener or a sibling connection thread just because they share a mutex.
//! `Mutex::lock().unwrap()` does exactly that cascade — the second thread
//! dies on the `PoisonError`. Every cross-thread lock on the serving path
//! goes through this helper instead, which recovers the guard: all the
//! protected state here (route maps, router placement state, the flight
//! ring) is valid after any partial update, so continuing beats dying.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Poisoning is a *hint*, not an invariant violation: the data under the
/// serving-path mutexes is never left in a torn state by a panic (inserts
/// and removes on maps are atomic from the guard's perspective), so the
/// right response is to keep serving, not to propagate the panic to every
/// thread that ever touches the lock.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_after_a_panicked_holder() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the mutex must actually be poisoned");
        let g = lock_unpoisoned(&m);
        assert_eq!(*g, vec![1, 2, 3], "data survives the poisoned holder");
    }

    #[test]
    fn plain_lock_path_is_unchanged() {
        let m = Mutex::new(7u32);
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
