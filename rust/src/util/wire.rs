//! Zero-copy wire-protocol layer for the streaming serve path (hifijson
//! style: slice lexing over the input buffer, strings borrowed from the
//! input unless they contain escapes, visitor-style field extraction that
//! skips unknown values without building a tree, and a token-event
//! serializer that writes into one reusable `Vec<u8>`).
//!
//! [`super::json::Json::parse`] is a tree-builder over the same [`Lexer`],
//! so the grammar (and its error behavior) exists exactly once; the serve
//! loop's hot path uses [`parse_request`] / [`EventWriter`] directly and
//! never allocates per token.

use std::borrow::Cow;

use super::json::JsonError;

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

/// Pull lexer over a byte slice. All scanning is bounds-checked: truncated
/// input yields `Err`, never a panic (the previous tree parser could index
/// out of bounds on a string cut mid-surrogate-pair).
pub struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Lexer<'a> {
    pub fn new(b: &'a [u8]) -> Lexer<'a> {
        Lexer { b, i: 0 }
    }

    /// Byte offset of the cursor — error reporting and span math.
    pub fn pos(&self) -> usize {
        self.i
    }

    pub fn error(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    pub fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    pub fn at_end(&self) -> bool {
        self.i == self.b.len()
    }

    pub fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    pub fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.error(&format!("expected '{}'", c as char)))
        }
    }

    pub fn lit(&mut self, s: &str) -> Result<(), JsonError> {
        if self.b.get(self.i..).map_or(false, |t| t.starts_with(s.as_bytes())) {
            self.i += s.len();
            Ok(())
        } else {
            Err(self.error(&format!("expected '{s}'")))
        }
    }

    /// Lex one string, returning the span between the quotes without copying
    /// or decoding. Escape *syntax* is validated here (so a skipped value is
    /// still syntax-checked); escape *semantics* (surrogate pairing,
    /// codepoint validity, UTF-8) are validated by [`RawStr::unescape`].
    pub fn raw_str(&mut self) -> Result<RawStr<'a>, JsonError> {
        self.eat(b'"')?;
        let start = self.i;
        let mut escaped = false;
        loop {
            match self.peek().ok_or_else(|| self.error("unterminated string"))? {
                b'"' => {
                    // lazylint: allow(panic-surface): start <= i <= len by the scan loop; this span cannot be out of bounds
                    let raw = &self.b[start..self.i];
                    self.i += 1;
                    return Ok(RawStr { raw, escaped });
                }
                b'\\' => {
                    escaped = true;
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.error("bad escape"))? {
                        b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => self.i += 1,
                        b'u' => {
                            self.i += 1;
                            match self.b.get(self.i..self.i + 4) {
                                Some(h) if h.iter().all(|c| c.is_ascii_hexdigit()) => self.i += 4,
                                _ => return Err(self.error("bad \\u")),
                            }
                        }
                        _ => return Err(self.error("bad escape")),
                    }
                }
                _ => self.i += 1,
            }
        }
    }

    /// Lex one number (JSON grammar superset: the previous parser accepted
    /// forms like `1.` and so does f64 parsing — kept for compatibility).
    pub fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        std::str::from_utf8(self.b.get(start..self.i).unwrap_or_default())
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| self.error("bad number"))
    }

    /// Skip one complete value (any type, arbitrarily nested) without
    /// allocating — how the visitor ignores unknown request fields.
    pub fn skip_value(&mut self) -> Result<(), JsonError> {
        match self.peek().ok_or_else(|| self.error("unexpected end"))? {
            b'n' => self.lit("null"),
            b't' => self.lit("true"),
            b'f' => self.lit("false"),
            b'"' => self.raw_str().map(|_| ()),
            b'-' | b'0'..=b'9' => self.number().map(|_| ()),
            b'[' => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.error("expected ',' or ']'")),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.raw_str()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.error("expected ',' or '}'")),
                    }
                }
            }
            c => Err(self.error(&format!("unexpected '{}'", c as char))),
        }
    }
}

// ---------------------------------------------------------------------------
// RawStr: borrowed string span + lazy unescape
// ---------------------------------------------------------------------------

/// A lexed string: the raw bytes between the quotes. Decoding is deferred so
/// the common case (no escapes) borrows straight from the input buffer.
pub struct RawStr<'a> {
    raw: &'a [u8],
    escaped: bool,
}

impl<'a> RawStr<'a> {
    /// Decode to text: `Cow::Borrowed` into the input when no escapes are
    /// present, an owned `String` otherwise. Validates UTF-8, surrogate
    /// pairing and codepoint validity.
    pub fn unescape(&self) -> Result<Cow<'a, str>, JsonError> {
        if !self.escaped {
            return std::str::from_utf8(self.raw).map(Cow::Borrowed).map_err(|_| JsonError {
                msg: "invalid utf8".to_string(),
                offset: 0,
            });
        }
        let mut out = String::with_capacity(self.raw.len());
        self.unescape_into(&mut out)?;
        Ok(Cow::Owned(out))
    }

    /// Decode into a caller-owned buffer (lets the visitor reuse storage).
    pub fn unescape_into(&self, out: &mut String) -> Result<(), JsonError> {
        let err = |msg: &str, at: usize| JsonError {
            msg: msg.to_string(),
            offset: at,
        };
        let b = self.raw;
        let mut i = 0;
        while i < b.len() {
            if b.get(i).copied() != Some(b'\\') {
                // copy the maximal escape-free run in one UTF-8 validation
                let start = i;
                while i < b.len() && b.get(i).copied() != Some(b'\\') {
                    i += 1;
                }
                out.push_str(
                    std::str::from_utf8(b.get(start..i).unwrap_or_default())
                        .map_err(|_| err("invalid utf8", start))?,
                );
                continue;
            }
            i += 1;
            let c = *b.get(i).ok_or_else(|| err("bad escape", i))?;
            i += 1;
            match c {
                b'"' => out.push('"'),
                b'\\' => out.push('\\'),
                b'/' => out.push('/'),
                b'b' => out.push('\u{8}'),
                b'f' => out.push('\u{c}'),
                b'n' => out.push('\n'),
                b'r' => out.push('\r'),
                b't' => out.push('\t'),
                b'u' => {
                    let cp = hex4(b, i).ok_or_else(|| err("bad \\u", i))?;
                    i += 4;
                    let ch = if (0xD800..0xDC00).contains(&cp) {
                        // high surrogate: a \uXXXX low surrogate must follow
                        if b.get(i..i + 2) != Some(b"\\u") {
                            return Err(err("lone surrogate", i));
                        }
                        i += 2;
                        let lo = hex4(b, i).ok_or_else(|| err("bad \\u", i))?;
                        i += 4;
                        if !(0xDC00..0xE000).contains(&lo) {
                            return Err(err("lone surrogate", i));
                        }
                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                    } else {
                        cp
                    };
                    out.push(char::from_u32(ch).ok_or_else(|| err("bad codepoint", i))?);
                }
                _ => return Err(err("bad escape", i)),
            }
        }
        Ok(())
    }
}

fn hex4(b: &[u8], i: usize) -> Option<u32> {
    let s = b.get(i..i + 4)?;
    u32::from_str_radix(std::str::from_utf8(s).ok()?, 16).ok()
}

// ---------------------------------------------------------------------------
// Request visitor
// ---------------------------------------------------------------------------

/// Fields of one wire request, extracted without building a `Json` tree.
/// Strings borrow from the input line unless they contained escapes. Unknown
/// fields are skipped (forward compatibility); the caller applies defaults
/// and required-field policy.
#[derive(Default)]
pub struct WireRequest<'a> {
    pub prompt: Option<Cow<'a, str>>,
    pub template: Option<Cow<'a, str>>,
    pub max_new: Option<f64>,
    pub class: Option<Cow<'a, str>>,
    pub stream: bool,
    pub cmd: Option<Cow<'a, str>>,
    pub id: Option<f64>,
}

/// Parse one request line (a top-level JSON object) in a single pass.
pub fn parse_request(line: &[u8]) -> Result<WireRequest<'_>, JsonError> {
    let mut lx = Lexer::new(line);
    let mut req = WireRequest::default();
    lx.ws();
    lx.eat(b'{')?;
    lx.ws();
    if lx.peek() != Some(b'}') {
        loop {
            lx.ws();
            let key = lx.raw_str()?;
            lx.ws();
            lx.eat(b':')?;
            lx.ws();
            let key = key.unescape()?;
            match &*key {
                "prompt" => req.prompt = Some(str_field(&mut lx, "prompt")?),
                "template" => req.template = Some(str_field(&mut lx, "template")?),
                "class" => req.class = Some(str_field(&mut lx, "class")?),
                "cmd" => req.cmd = Some(str_field(&mut lx, "cmd")?),
                "max_new" => req.max_new = Some(num_field(&mut lx, "max_new")?),
                "id" => req.id = Some(num_field(&mut lx, "id")?),
                "stream" => {
                    req.stream = match lx.peek() {
                        Some(b't') => {
                            lx.lit("true")?;
                            true
                        }
                        Some(b'f') => {
                            lx.lit("false")?;
                            false
                        }
                        _ => return Err(lx.error("'stream' is not a bool")),
                    }
                }
                _ => lx.skip_value()?,
            }
            lx.ws();
            match lx.peek() {
                Some(b',') => {
                    lx.eat(b',')?;
                }
                Some(b'}') => break,
                _ => return Err(lx.error("expected ',' or '}'")),
            }
        }
    }
    lx.eat(b'}')?;
    lx.ws();
    if !lx.at_end() {
        return Err(lx.error("trailing characters"));
    }
    Ok(req)
}

fn str_field<'a>(lx: &mut Lexer<'a>, name: &str) -> Result<Cow<'a, str>, JsonError> {
    if lx.peek() != Some(b'"') {
        return Err(lx.error(&format!("'{name}' is not a string")));
    }
    lx.raw_str()?.unescape()
}

fn num_field(lx: &mut Lexer<'_>, name: &str) -> Result<f64, JsonError> {
    if !matches!(lx.peek(), Some(b'-') | Some(b'0'..=b'9')) {
        return Err(lx.error(&format!("'{name}' is not a number")));
    }
    lx.number()
}

// ---------------------------------------------------------------------------
// EventWriter: reusable token-event serializer
// ---------------------------------------------------------------------------

/// Serializes token-event lines into one owned buffer that is reused across
/// calls, so the per-token streaming path performs no allocation once the
/// buffer has grown to the working size.
pub struct EventWriter {
    buf: Vec<u8>,
}

impl EventWriter {
    pub fn new() -> EventWriter {
        EventWriter {
            buf: Vec::with_capacity(128),
        }
    }

    /// One `token` event as a JSON line (trailing `\n` included). The
    /// returned slice is valid until the next call.
    pub fn token(&mut self, id: u64, text: &str, n: usize, first: bool) -> &[u8] {
        self.buf.clear();
        self.buf.extend_from_slice(b"{\"event\":\"token\",\"id\":");
        push_u64(&mut self.buf, id);
        self.buf.extend_from_slice(b",\"n\":");
        push_u64(&mut self.buf, n as u64);
        self.buf.extend_from_slice(b",\"first\":");
        self.buf
            .extend_from_slice(if first { b"true" } else { b"false" });
        self.buf.extend_from_slice(b",\"text\":");
        push_escaped(&mut self.buf, text);
        self.buf.extend_from_slice(b"}\n");
        &self.buf
    }
}

impl Default for EventWriter {
    fn default() -> Self {
        EventWriter::new()
    }
}

/// Decimal u64 without going through `format!` (which allocates).
fn push_u64(out: &mut Vec<u8>, mut x: u64) {
    let mut tmp = [0u8; 20];
    let mut n = 0;
    loop {
        // lazylint: allow(panic-surface): n < 20 == tmp.len() — a u64 has at most 20 decimal digits
        tmp[n] = b'0' + (x % 10) as u8;
        x /= 10;
        n += 1;
        if x == 0 {
            break;
        }
    }
    for k in (0..n).rev() {
        // lazylint: allow(panic-surface): k < n <= tmp.len() by the digit loop above
        out.push(tmp[k]);
    }
}

/// JSON string escape into a byte buffer — same escape set as the tree
/// serializer in `util::json`, so event lines parse with `Json::parse`.
pub fn push_escaped(out: &mut Vec<u8>, s: &str) {
    out.push(b'"');
    for c in s.chars() {
        match c {
            '"' => out.extend_from_slice(b"\\\""),
            '\\' => out.extend_from_slice(b"\\\\"),
            '\n' => out.extend_from_slice(b"\\n"),
            '\r' => out.extend_from_slice(b"\\r"),
            '\t' => out.extend_from_slice(b"\\t"),
            c if (c as u32) < 0x20 => {
                out.extend_from_slice(b"\\u00");
                let v = c as u32;
                const HEX: &[u8; 16] = b"0123456789abcdef";
                // lazylint: allow(panic-surface): v >> 4 is < 16 == HEX.len() for v < 0x20
                out.push(HEX[(v >> 4) as usize]);
                // lazylint: allow(panic-surface): v & 0xf is < 16 == HEX.len()
                out.push(HEX[(v & 0xf) as usize]);
            }
            c => {
                let mut utf8 = [0u8; 4];
                out.extend_from_slice(c.encode_utf8(&mut utf8).as_bytes());
            }
        }
    }
    out.push(b'"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::property_test;

    #[test]
    fn raw_str_borrows_without_escapes() {
        let mut lx = Lexer::new(b"\"plain ascii and \xc3\xa9\"");
        let s = lx.raw_str().unwrap();
        match s.unescape().unwrap() {
            Cow::Borrowed(v) => assert_eq!(v, "plain ascii and é"),
            Cow::Owned(_) => panic!("escape-free string must borrow"),
        }
    }

    #[test]
    fn raw_str_owns_with_escapes() {
        let mut lx = Lexer::new(br#""a\nb\u0041\ud83d\ude00""#);
        let s = lx.raw_str().unwrap();
        match s.unescape().unwrap() {
            Cow::Owned(v) => assert_eq!(v, "a\nbA😀"),
            Cow::Borrowed(_) => panic!("escaped string must decode"),
        }
    }

    #[test]
    fn truncated_inputs_error_not_panic() {
        // the old tree parser indexed out of bounds on strings cut
        // mid-surrogate-pair; every truncation must now be a clean Err
        for src in [
            &b"\"abc"[..],
            b"\"\\",
            b"\"\\u",
            b"\"\\u00",
            b"\"\\ud800",
            b"\"\\ud800\\",
            b"\"\\ud800\\u",
            b"\"\\ud800\\udc0",
        ] {
            let mut lx = Lexer::new(src);
            let r = lx.raw_str().and_then(|s| s.unescape().map(|_| ()));
            assert!(r.is_err(), "{:?} must be rejected", src);
        }
    }

    #[test]
    fn surrogate_validation() {
        // lone high surrogate, and a high surrogate followed by a non-low
        for src in [&br#""\ud800""#[..], br#""\ud800\u0041""#] {
            let mut lx = Lexer::new(src);
            let r = lx.raw_str().unwrap().unescape();
            assert!(r.is_err(), "{:?} must be rejected", src);
        }
        // a valid pair decodes
        let mut lx = Lexer::new(br#""\ud83d\ude00""#);
        assert_eq!(lx.raw_str().unwrap().unescape().unwrap(), "😀");
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut lx = Lexer::new(b"\"\xff\xfe\"");
        assert!(lx.raw_str().unwrap().unescape().is_err());
        // ...also when the bad bytes sit next to an escape
        let mut lx = Lexer::new(b"\"\\n\xff\"");
        assert!(lx.raw_str().unwrap().unescape().is_err());
    }

    #[test]
    fn skip_value_spans_nested() {
        let src = br#"{"a":[1,{"b":"x\n"},null,true],"c":-1e3} tail"#;
        let mut lx = Lexer::new(src);
        lx.skip_value().unwrap();
        lx.ws();
        assert_eq!(lx.pos(), src.len() - 4);
    }

    #[test]
    fn skip_value_rejects_malformed() {
        for src in [&b"[1,]"[..], b"{\"a\" 1}", b"{\"a\":}", b"[", b"nul"] {
            let mut lx = Lexer::new(src);
            assert!(lx.skip_value().is_err(), "{:?} must be rejected", src);
        }
    }

    #[test]
    fn request_extraction() {
        let line = br#"{"prompt":"hi\n","max_new":12,"class":"interactive","stream":true,"future_field":{"deep":[1,2]},"template":"gsm"}"#;
        let r = parse_request(line).unwrap();
        assert_eq!(r.prompt.as_deref(), Some("hi\n"));
        assert_eq!(r.template.as_deref(), Some("gsm"));
        assert_eq!(r.class.as_deref(), Some("interactive"));
        assert_eq!(r.max_new, Some(12.0));
        assert!(r.stream);
        assert!(r.cmd.is_none());
    }

    #[test]
    fn request_defaults_and_commands() {
        let r = parse_request(br#"{"cmd":"trace","id":7}"#).unwrap();
        assert_eq!(r.cmd.as_deref(), Some("trace"));
        assert_eq!(r.id, Some(7.0));
        assert!(r.prompt.is_none());
        assert!(!r.stream);
        let r = parse_request(b"{}").unwrap();
        assert!(r.prompt.is_none() && r.cmd.is_none());
    }

    #[test]
    fn request_type_errors() {
        assert!(parse_request(br#"{"prompt":1}"#).is_err());
        assert!(parse_request(br#"{"max_new":"x"}"#).is_err());
        assert!(parse_request(br#"{"stream":"yes"}"#).is_err());
        assert!(parse_request(br#"{"prompt":"a"} extra"#).is_err());
        assert!(parse_request(b"[1]").is_err());
    }

    #[test]
    fn request_prompt_borrows_when_clean() {
        let line = br#"{"prompt":"no escapes here"}"#;
        let r = parse_request(line).unwrap();
        match r.prompt.unwrap() {
            Cow::Borrowed(v) => assert_eq!(v, "no escapes here"),
            Cow::Owned(_) => panic!("clean prompt must borrow from the line"),
        }
    }

    #[test]
    fn event_writer_lines_parse() {
        let mut w = EventWriter::new();
        let line = w.token(42, "a\"b\\c\nd\té😀\u{1}", 3, true);
        assert_eq!(*line.last().unwrap(), b'\n');
        let v = Json::parse(std::str::from_utf8(line).unwrap().trim_end()).unwrap();
        assert_eq!(v.str_at("event").unwrap(), "token");
        assert_eq!(v.usize_at("id").unwrap(), 42);
        assert_eq!(v.usize_at("n").unwrap(), 3);
        assert!(v.get("first").unwrap().as_bool().unwrap());
        assert_eq!(v.str_at("text").unwrap(), "a\"b\\c\nd\té😀\u{1}");
    }

    #[test]
    fn event_writer_reuses_buffer() {
        let mut w = EventWriter::new();
        let long = "x".repeat(64);
        w.token(1, &long, 1, true);
        let cap = w.buf.capacity();
        for n in 2..50 {
            let line = w.token(1, &long, n, false);
            let v = Json::parse(std::str::from_utf8(line).unwrap().trim_end()).unwrap();
            assert_eq!(v.usize_at("n").unwrap(), n);
        }
        assert_eq!(w.buf.capacity(), cap, "steady-state tokens must not grow the buffer");
    }

    #[test]
    fn event_writer_roundtrips_random_text() {
        property_test("event_writer_roundtrip", 64, |r| {
            let mut text = String::new();
            for _ in 0..r.below(40) {
                // bias toward the characters that exercise escaping and
                // multi-byte UTF-8 boundaries
                let c = match r.below(8) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => char::from_u32(r.below(0x20) as u32).unwrap(),
                    4 => 'é',
                    5 => '😀',
                    _ => char::from_u32(0x20 + r.below(0x5e) as u32).unwrap(),
                };
                text.push(c);
            }
            let mut w = EventWriter::new();
            let line = w.token(9, &text, 1, false);
            let v = Json::parse(std::str::from_utf8(line).unwrap().trim_end()).unwrap();
            assert_eq!(v.str_at("text").unwrap(), text);
        });
    }
}
