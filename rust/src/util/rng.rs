//! Deterministic RNG (SplitMix64 seeding + Xoshiro256**) — the offline crate
//! set has no `rand`, and determinism is load-bearing for the simulator and
//! the in-house property tests (failure seeds are reported and replayable).

/// SplitMix64: used to expand a u64 seed into Xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-sequence / per-worker RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-300).ln()
    }

    /// Log-normal given the mean/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Geometric: number of failures before first success, p in (0,1].
    pub fn geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        (self.f64().max(1e-300).ln() / (1.0 - p).ln()) as u64
    }

    /// Zipf-ish heavy-tail integer in [1, n] with exponent `a` (rejection-free
    /// inverse-CDF approximation, adequate for workload shaping).
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        let u = self.f64();
        let x = ((n as f64).powf(1.0 - a) * u + (1.0 - u)).powf(1.0 / (1.0 - a));
        (x as usize).clamp(1, n)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample one element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(10);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(12);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(13);
        let mut ones = 0;
        for _ in 0..10_000 {
            let x = r.zipf(100, 1.2);
            assert!((1..=100).contains(&x));
            if x == 1 {
                ones += 1;
            }
        }
        assert!(ones > 1_000, "zipf should concentrate mass at 1, got {ones}");
    }
}
