//! Serving metrics: step latencies, per-request timing, throughput counters.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Rolling recorder for one engine's decode loop.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    /// Wall time of each decode step (seconds).
    pub step_latencies: Vec<f64>,
    /// Wall time of each prefill (seconds).
    pub prefill_latencies: Vec<f64>,
    /// Wall time spent inside eviction decisions (seconds).
    pub eviction_time: f64,
    pub eviction_count: u64,
    /// Rows preempted because the shared block pool ran dry (paged mode).
    pub preemptions: u64,
    /// Preempted rows re-admitted in recompute mode: decode state and
    /// tracker records restored, generation continued (not restarted).
    pub resumes: u64,
    /// Tokens re-prefilled by recompute-mode resumes (the one-pass prefill
    /// cost paid instead of regenerating every token).
    pub recomputed_tokens: u64,
    /// Resumes that could not recompute (fed stream outgrew the prefill
    /// bucket, or no pool) and fell back to a restart from the prompt.
    pub resume_fallbacks: u64,
    /// Admissions that skipped the prefill executable entirely because a
    /// prefix-cache entry covered the full prompt (physical paging).
    pub prefill_skips: u64,
    /// Host tier: evicted-block groups parked instead of destroyed.
    pub demoted_blocks: u64,
    /// Host tier: parked entries swapped back in because a token's score
    /// re-crossed the keep threshold (recurrence-driven promotion).
    pub promotions: u64,
    /// Host tier: tokens restored by those promotions — each one a K/V row
    /// the paper's recurrence phenomenon would otherwise have lost.
    pub false_evictions_avoided: u64,
    /// Host tier: bytes copied device→host (demotions + swap preemptions).
    pub swap_out_bytes: u64,
    /// Host tier: bytes copied host→device (promotions + swap resumes).
    pub swap_in_bytes: u64,
    /// Preemptions that parked the row's whole table (swap mode) instead of
    /// taking a recompute snapshot.
    pub swap_preempts: u64,
    /// Park attempts the tier refused (byte budget full of pinned state) —
    /// those demotions stayed destructive / preemptions fell back.
    pub tier_rejects: u64,
    /// Tokens produced (all rows).
    pub tokens_out: u64,
    /// Live-token counts sampled per step (for memory curves), per row.
    pub live_counts: Vec<usize>,
    started: Option<Instant>,
    pub wall: f64,
}

impl EngineMetrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.wall += t0.elapsed().as_secs_f64();
        }
    }

    pub fn record_step(&mut self, d: Duration, new_tokens: u64) {
        self.step_latencies.push(d.as_secs_f64());
        self.tokens_out += new_tokens;
    }

    pub fn record_prefill(&mut self, d: Duration) {
        self.prefill_latencies.push(d.as_secs_f64());
    }

    pub fn record_eviction(&mut self, d: Duration) {
        self.eviction_time += d.as_secs_f64();
        self.eviction_count += 1;
    }

    /// Decode throughput in tokens/second over recorded steps.
    pub fn throughput(&self) -> f64 {
        let total: f64 = self.step_latencies.iter().sum();
        if total == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / total
        }
    }

    /// Mean per-token decode latency in ms.
    pub fn avg_latency_ms(&self) -> f64 {
        if self.tokens_out == 0 {
            return f64::NAN;
        }
        self.step_latencies.iter().sum::<f64>() * 1e3 / self.tokens_out as f64
    }

    pub fn step_summary_ms(&self) -> Summary {
        let ms: Vec<f64> = self.step_latencies.iter().map(|x| x * 1e3).collect();
        Summary::of(&ms)
    }
}

/// Per-request timing captured by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RequestMetrics {
    pub queued_s: f64,
    pub ttft_s: f64,
    pub total_s: f64,
    pub tokens_out: usize,
    pub evictions: usize,
}

/// Instantaneous block-pool gauges (paged-KV mode). Exported by
/// `Engine::pool_gauges` and attached to server responses so clients and
/// scrapers see global memory pressure alongside each completion.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauges {
    pub free_blocks: usize,
    pub total_blocks: usize,
    /// Fraction of the pool allocated, in [0, 1].
    pub utilization: f64,
    /// Cumulative preemption count for the engine.
    pub preemptions: u64,
    /// Cumulative recompute-mode resumes (preempted rows that continued
    /// where they stopped instead of restarting).
    pub resumes: u64,
    /// Cumulative tokens re-prefilled by those resumes.
    pub recomputed_tokens: u64,
    /// Blocks currently referenced more than once (prefix sharing / CoW).
    pub shared_blocks: usize,
    /// Cumulative prompt-prefix cache hits (a hit = whole blocks reused).
    pub prefix_hits: u64,
    /// Cumulative prompt-prefix cache misses.
    pub prefix_misses: u64,
    /// Live prefix-cache entries.
    pub prefix_entries: usize,
    /// Blocks the prefix cache currently pins (refs held by the cache).
    pub prefix_pinned_blocks: usize,
    /// Cumulative admissions that skipped prefill via a full-prompt hit.
    pub prefix_prefill_skips: u64,
    /// Total physical K/V bytes of the backend's block arenas (K + V) —
    /// fixed by pool geometry, independent of batch × max_len.
    pub kv_arena_bytes: usize,
    /// The share of `kv_arena_bytes` in live (allocated) blocks right now.
    pub kv_bytes_in_use: usize,
    /// Host tier: parked entries resident right now (0 without a tier).
    pub parked_blocks: usize,
    /// Host tier: bytes those entries occupy.
    pub parked_bytes: usize,
    /// Cumulative evicted-block groups parked instead of destroyed.
    pub demoted_blocks: u64,
    /// Cumulative recurrence-driven promotions (entries swapped back in).
    pub promotions: u64,
    /// Cumulative tokens restored by promotions.
    pub false_evictions_avoided: u64,
    /// Cumulative bytes copied device→host by the tier.
    pub swap_out_bytes: u64,
    /// Cumulative bytes copied host→device by the tier.
    pub swap_in_bytes: u64,
    /// Cumulative swap-mode preemptions (whole table parked, no recompute).
    pub swap_preempts: u64,
    /// Cumulative unpinned tier entries destroyed under byte pressure —
    /// each one a demotion that silently became a plain eviction.
    pub tier_shed_blocks: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::default();
        m.record_step(Duration::from_millis(10), 4);
        m.record_step(Duration::from_millis(10), 4);
        assert!((m.throughput() - 400.0).abs() < 1.0);
        assert!((m.avg_latency_ms() - 2.5).abs() < 0.01);
    }

    #[test]
    fn empty_is_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert!(m.avg_latency_ms().is_nan());
    }

    #[test]
    fn wall_clock_accumulates() {
        let mut m = EngineMetrics::default();
        m.start();
        std::thread::sleep(Duration::from_millis(5));
        m.stop();
        assert!(m.wall >= 0.004);
    }
}
