//! Serving metrics: step latencies, per-request timing, throughput counters.
//!
//! Latency series are held as bounded streaming histograms
//! (`telemetry::StreamingHistogram`), not growing vectors: a long-running
//! serve loop records millions of steps without the recorder itself
//! becoming a memory leak. Throughput and mean-latency math is exact (the
//! histograms track exact `n`/`sum`); percentiles are bucket-interpolated.
//! Benches that need the raw per-step series (e.g. windowed checkpoint
//! latency in table 7) opt into a bounded side log via `enable_step_log`.

use std::time::{Duration, Instant};

use crate::telemetry::hist::StreamingHistogram;
use crate::telemetry::registry::MetricKind;
use crate::util::stats::Summary;

/// Rolling recorder for one engine's decode loop.
#[derive(Debug)]
pub struct EngineMetrics {
    /// Wall time of each decode step (histogram over milliseconds).
    pub step_hist_ms: StreamingHistogram,
    /// Wall time of each prefill (histogram over milliseconds).
    pub prefill_hist_ms: StreamingHistogram,
    /// Time-to-first-token per finished request (ms).
    pub ttft_hist_ms: StreamingHistogram,
    /// Time-per-output-token per finished request, excluding the first (ms).
    pub tpot_hist_ms: StreamingHistogram,
    /// Queue wait per admission (ms).
    pub queue_wait_hist_ms: StreamingHistogram,
    /// Wall time of each eviction pass (ms).
    pub evict_hist_ms: StreamingHistogram,
    /// Live-set sizes sampled per row per step (tokens).
    pub live_hist: StreamingHistogram,
    /// Decode steps recorded.
    pub steps: u64,
    /// Total wall seconds inside decode steps (exact; drives throughput).
    pub step_time_s: f64,
    /// Wall time spent inside eviction decisions (seconds).
    pub eviction_time: f64,
    pub eviction_count: u64,
    /// Rows preempted because the shared block pool ran dry (paged mode).
    pub preemptions: u64,
    /// Preempted rows re-admitted in recompute mode: decode state and
    /// tracker records restored, generation continued (not restarted).
    pub resumes: u64,
    /// Tokens re-prefilled by recompute-mode resumes (the one-pass prefill
    /// cost paid instead of regenerating every token).
    pub recomputed_tokens: u64,
    /// Resumes that could not recompute (fed stream outgrew the prefill
    /// bucket, or no pool) and fell back to a restart from the prompt.
    pub resume_fallbacks: u64,
    /// Admissions that skipped the prefill executable entirely because a
    /// prefix-cache entry covered the full prompt (physical paging).
    pub prefill_skips: u64,
    /// Host tier: evicted-block groups parked instead of destroyed.
    pub demoted_blocks: u64,
    /// Host tier: parked entries swapped back in because a token's score
    /// re-crossed the keep threshold (recurrence-driven promotion).
    pub promotions: u64,
    /// Host tier: tokens restored by those promotions — each one a K/V row
    /// the paper's recurrence phenomenon would otherwise have lost.
    pub false_evictions_avoided: u64,
    /// Host tier: bytes copied device→host (demotions + swap preemptions).
    pub swap_out_bytes: u64,
    /// Host tier: bytes copied host→device (promotions + swap resumes).
    pub swap_in_bytes: u64,
    /// Preemptions that parked the row's whole table (swap mode) instead of
    /// taking a recompute snapshot.
    pub swap_preempts: u64,
    /// Park attempts the tier refused (byte budget full of pinned state) —
    /// those demotions stayed destructive / preemptions fell back.
    pub tier_rejects: u64,
    /// Tokens produced (all rows).
    pub tokens_out: u64,
    /// Requests finished (any reason).
    pub requests_finished: u64,
    /// Token events actually handed to a streaming client as they were
    /// decoded (the serve loop increments this when it forwards a drained
    /// event to a route that asked for `"stream": true`).
    pub streamed_tokens: u64,
    /// Rows/requests torn down by client cancellation or disconnect
    /// (active-row aborts and discarded preempted snapshots alike).
    pub cancelled_rows: u64,
    /// Optional bounded raw per-step latency log (seconds), for benches
    /// that window the series; `None` in serving (bounded memory).
    step_log: Option<(Vec<f64>, usize)>,
    started: Option<Instant>,
    pub wall: f64,
}

impl Default for EngineMetrics {
    fn default() -> EngineMetrics {
        EngineMetrics {
            step_hist_ms: StreamingHistogram::latency_ms(),
            prefill_hist_ms: StreamingHistogram::latency_ms(),
            ttft_hist_ms: StreamingHistogram::latency_ms(),
            tpot_hist_ms: StreamingHistogram::latency_ms(),
            queue_wait_hist_ms: StreamingHistogram::latency_ms(),
            evict_hist_ms: StreamingHistogram::latency_ms(),
            live_hist: StreamingHistogram::counts(),
            steps: 0,
            step_time_s: 0.0,
            eviction_time: 0.0,
            eviction_count: 0,
            preemptions: 0,
            resumes: 0,
            recomputed_tokens: 0,
            resume_fallbacks: 0,
            prefill_skips: 0,
            demoted_blocks: 0,
            promotions: 0,
            false_evictions_avoided: 0,
            swap_out_bytes: 0,
            swap_in_bytes: 0,
            swap_preempts: 0,
            tier_rejects: 0,
            tokens_out: 0,
            requests_finished: 0,
            streamed_tokens: 0,
            cancelled_rows: 0,
            step_log: None,
            started: None,
            wall: 0.0,
        }
    }
}

impl EngineMetrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.wall += t0.elapsed().as_secs_f64();
        }
    }

    /// Keep a raw per-step latency log of at most `cap` entries alongside
    /// the histogram (bench/analysis use only).
    pub fn enable_step_log(&mut self, cap: usize) {
        self.step_log = Some((Vec::with_capacity(cap.min(4096)), cap));
    }

    /// The raw step-latency series (seconds), if `enable_step_log` was on.
    pub fn step_log(&self) -> &[f64] {
        self.step_log.as_ref().map(|(v, _)| v.as_slice()).unwrap_or(&[])
    }

    pub fn record_step(&mut self, d: Duration, new_tokens: u64) {
        let s = d.as_secs_f64();
        self.steps += 1;
        self.step_time_s += s;
        self.step_hist_ms.observe(s * 1e3);
        self.tokens_out += new_tokens;
        if let Some((log, cap)) = self.step_log.as_mut() {
            if log.len() < *cap {
                log.push(s);
            }
        }
    }

    pub fn record_prefill(&mut self, d: Duration) {
        self.prefill_hist_ms.observe(d.as_secs_f64() * 1e3);
    }

    pub fn record_eviction(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        self.eviction_time += s;
        self.eviction_count += 1;
        self.evict_hist_ms.observe(s * 1e3);
    }

    pub fn record_queue_wait(&mut self, queued_s: f64) {
        self.queue_wait_hist_ms.observe(queued_s * 1e3);
    }

    /// Per-request timings at completion: TTFT, and TPOT over the tokens
    /// after the first (undefined for single-token outputs).
    pub fn record_finish(&mut self, ttft_s: f64, total_s: f64, tokens: usize) {
        self.requests_finished += 1;
        self.ttft_hist_ms.observe(ttft_s * 1e3);
        if tokens > 1 {
            let tpot = (total_s - ttft_s).max(0.0) / (tokens - 1) as f64;
            self.tpot_hist_ms.observe(tpot * 1e3);
        }
    }

    pub fn record_live(&mut self, live_tokens: usize) {
        self.live_hist.observe(live_tokens as f64);
    }

    /// Decode throughput in tokens/second over recorded steps.
    pub fn throughput(&self) -> f64 {
        if self.step_time_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.step_time_s
        }
    }

    /// Mean per-token decode latency in ms.
    pub fn avg_latency_ms(&self) -> f64 {
        if self.tokens_out == 0 {
            return f64::NAN;
        }
        self.step_time_s * 1e3 / self.tokens_out as f64
    }

    pub fn step_summary_ms(&self) -> Summary {
        self.step_hist_ms.summary()
    }
}

/// Per-request timing captured by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RequestMetrics {
    pub queued_s: f64,
    pub ttft_s: f64,
    pub total_s: f64,
    pub tokens_out: usize,
    pub evictions: usize,
}

/// Instantaneous block-pool gauges (paged-KV mode). Exported by
/// `Engine::pool_gauges` and attached to server responses so clients and
/// scrapers see global memory pressure alongside each completion.
///
/// `fields()` is the single source of truth for the export surface: the
/// server's `pool` JSON and the `/metrics` exposition both iterate it, so
/// a field added here is automatically visible in both (and the parity
/// test fails if either path hand-rolls a divergent list).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauges {
    pub free_blocks: usize,
    pub total_blocks: usize,
    /// Fraction of the pool allocated, in [0, 1].
    pub utilization: f64,
    /// Cumulative preemption count for the engine.
    pub preemptions: u64,
    /// Cumulative recompute-mode resumes (preempted rows that continued
    /// where they stopped instead of restarting).
    pub resumes: u64,
    /// Cumulative tokens re-prefilled by those resumes.
    pub recomputed_tokens: u64,
    /// Blocks currently referenced more than once (prefix sharing / CoW).
    pub shared_blocks: usize,
    /// Cumulative prompt-prefix cache hits (a hit = whole blocks reused).
    pub prefix_hits: u64,
    /// Cumulative prompt-prefix cache misses.
    pub prefix_misses: u64,
    /// Live prefix-cache entries.
    pub prefix_entries: usize,
    /// Blocks the prefix cache currently pins (refs held by the cache).
    pub prefix_pinned_blocks: usize,
    /// Cumulative admissions that skipped prefill via a full-prompt hit.
    pub prefix_prefill_skips: u64,
    /// Total physical K/V bytes of the backend's block arenas (K + V) —
    /// fixed by pool geometry, independent of batch × max_len.
    pub kv_arena_bytes: usize,
    /// The share of `kv_arena_bytes` in live (allocated) blocks right now.
    pub kv_bytes_in_use: usize,
    /// Host tier: parked entries resident right now (0 without a tier).
    pub parked_blocks: usize,
    /// Host tier: bytes those entries occupy.
    pub parked_bytes: usize,
    /// Cumulative evicted-block groups parked instead of destroyed.
    pub demoted_blocks: u64,
    /// Cumulative recurrence-driven promotions (entries swapped back in).
    pub promotions: u64,
    /// Cumulative tokens restored by promotions.
    pub false_evictions_avoided: u64,
    /// Cumulative bytes copied device→host by the tier.
    pub swap_out_bytes: u64,
    /// Cumulative bytes copied host→device by the tier.
    pub swap_in_bytes: u64,
    /// Cumulative swap-mode preemptions (whole table parked, no recompute).
    pub swap_preempts: u64,
    /// Cumulative unpinned tier entries destroyed under byte pressure —
    /// each one a demotion that silently became a plain eviction.
    pub tier_shed_blocks: u64,
    /// Cumulative park attempts the tier refused (byte budget exhausted by
    /// pinned state) — those demotions stayed destructive.
    pub tier_rejects: u64,
}

impl PoolGauges {
    /// Every exported field as `(name, value, kind)`. Built by exhaustive
    /// destructuring: adding a `PoolGauges` field without extending this
    /// list is a compile error, which is what keeps the server JSON and
    /// the `/metrics` exposition in lockstep.
    pub fn fields(&self) -> Vec<(&'static str, f64, MetricKind)> {
        use MetricKind::{Counter, Gauge};
        let PoolGauges {
            free_blocks,
            total_blocks,
            utilization,
            preemptions,
            resumes,
            recomputed_tokens,
            shared_blocks,
            prefix_hits,
            prefix_misses,
            prefix_entries,
            prefix_pinned_blocks,
            prefix_prefill_skips,
            kv_arena_bytes,
            kv_bytes_in_use,
            parked_blocks,
            parked_bytes,
            demoted_blocks,
            promotions,
            false_evictions_avoided,
            swap_out_bytes,
            swap_in_bytes,
            swap_preempts,
            tier_shed_blocks,
            tier_rejects,
        } = *self;
        vec![
            ("free_blocks", free_blocks as f64, Gauge),
            ("total_blocks", total_blocks as f64, Gauge),
            ("utilization", utilization, Gauge),
            ("preemptions", preemptions as f64, Counter),
            ("resumes", resumes as f64, Counter),
            ("recomputed_tokens", recomputed_tokens as f64, Counter),
            ("shared_blocks", shared_blocks as f64, Gauge),
            ("prefix_hits", prefix_hits as f64, Counter),
            ("prefix_misses", prefix_misses as f64, Counter),
            ("prefix_entries", prefix_entries as f64, Gauge),
            ("prefix_pinned_blocks", prefix_pinned_blocks as f64, Gauge),
            ("prefix_prefill_skips", prefix_prefill_skips as f64, Counter),
            ("kv_arena_bytes", kv_arena_bytes as f64, Gauge),
            ("kv_bytes_in_use", kv_bytes_in_use as f64, Gauge),
            ("parked_blocks", parked_blocks as f64, Gauge),
            ("parked_bytes", parked_bytes as f64, Gauge),
            ("demoted_blocks", demoted_blocks as f64, Counter),
            ("promotions", promotions as f64, Counter),
            (
                "false_evictions_avoided",
                false_evictions_avoided as f64,
                Counter,
            ),
            ("swap_out_bytes", swap_out_bytes as f64, Counter),
            ("swap_in_bytes", swap_in_bytes as f64, Counter),
            ("swap_preempts", swap_preempts as f64, Counter),
            ("tier_shed_blocks", tier_shed_blocks as f64, Counter),
            ("tier_rejects", tier_rejects as f64, Counter),
        ]
    }

    /// Publish every field into a registry under the
    /// `lazyeviction_pool_` namespace (counters clamped monotone there).
    pub fn publish(&self, reg: &crate::telemetry::Registry) {
        self.publish_with(reg, None);
    }

    /// Fleet variant: publish under `lazyeviction_pool_<field>{replica="r"}`
    /// so N replicas' pools coexist in one registry. The exposition groups
    /// the labeled samples into one family per field.
    pub fn publish_labeled(&self, reg: &crate::telemetry::Registry, replica: usize) {
        self.publish_with(reg, Some(replica));
    }

    fn publish_with(&self, reg: &crate::telemetry::Registry, replica: Option<usize>) {
        for (name, value, kind) in self.fields() {
            let base = format!("{}{name}", crate::telemetry::names::POOL_PREFIX);
            let metric = match replica {
                Some(r) => crate::telemetry::labeled(&base, "replica", r),
                None => base,
            };
            match kind {
                MetricKind::Counter => reg.set_counter(&metric, value as u64),
                MetricKind::Gauge => reg.set_gauge(&metric, value),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::default();
        m.record_step(Duration::from_millis(10), 4);
        m.record_step(Duration::from_millis(10), 4);
        assert!((m.throughput() - 400.0).abs() < 1.0);
        assert!((m.avg_latency_ms() - 2.5).abs() < 0.01);
    }

    #[test]
    fn empty_is_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert!(m.avg_latency_ms().is_nan());
    }

    #[test]
    fn wall_clock_accumulates() {
        let mut m = EngineMetrics::default();
        m.start();
        std::thread::sleep(Duration::from_millis(5));
        m.stop();
        assert!(m.wall >= 0.004);
    }

    #[test]
    fn step_summary_mean_is_exact() {
        let mut m = EngineMetrics::default();
        m.record_step(Duration::from_millis(10), 1);
        m.record_step(Duration::from_millis(30), 1);
        let s = m.step_summary_ms();
        assert_eq!(s.n, 2);
        assert!((s.mean - 20.0).abs() < 1e-9);
        assert_eq!(s.min, 10.0);
        assert_eq!(s.max, 30.0);
    }

    #[test]
    fn step_log_is_opt_in_and_bounded() {
        let mut m = EngineMetrics::default();
        m.record_step(Duration::from_millis(1), 1);
        assert!(m.step_log().is_empty(), "serving never keeps raw series");
        m.enable_step_log(2);
        for _ in 0..5 {
            m.record_step(Duration::from_millis(1), 1);
        }
        assert_eq!(m.step_log().len(), 2);
        assert_eq!(m.steps, 6);
    }

    #[test]
    fn finish_records_ttft_and_tpot() {
        let mut m = EngineMetrics::default();
        // 100ms TTFT, then 9 more tokens over 900ms → TPOT 100ms
        m.record_finish(0.1, 1.0, 10);
        assert_eq!(m.requests_finished, 1);
        assert_eq!(m.ttft_hist_ms.n(), 1);
        assert!((m.ttft_hist_ms.sum() - 100.0).abs() < 1e-9);
        assert_eq!(m.tpot_hist_ms.n(), 1);
        assert!((m.tpot_hist_ms.sum() - 100.0).abs() < 1e-9);
        // single-token request: TTFT only, TPOT undefined
        m.record_finish(0.05, 0.05, 1);
        assert_eq!(m.ttft_hist_ms.n(), 2);
        assert_eq!(m.tpot_hist_ms.n(), 1);
    }

    #[test]
    fn pool_gauge_fields_cover_every_field() {
        let g = PoolGauges {
            tier_rejects: 3,
            ..Default::default()
        };
        let fields = g.fields();
        // 24 fields today; the destructuring in fields() makes forgetting
        // a new one a compile error, this pins against deletions
        assert_eq!(fields.len(), 24);
        let names: Vec<&str> = fields.iter().map(|f| f.0).collect();
        assert!(names.contains(&"tier_rejects"));
        assert!(names.contains(&"utilization"));
        let tr = fields.iter().find(|f| f.0 == "tier_rejects").unwrap();
        assert_eq!(tr.1, 3.0);
        assert_eq!(tr.2, MetricKind::Counter);
    }
}
