//! Fig. 5: accuracy–budget trade-off curves, r ∈ {0.1..0.9}, four panels
//! (DS-Llama-8B / DS-Qwen-7B × GSM8K / MATH-500). The reproduction target:
//! all methods converge near FullKV at large r; under tight budgets the
//! greedy baselines collapse while LazyEviction degrades gracefully.

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::util::json::Json;

const RS: [f64; 5] = [0.1, 0.3, 0.5, 0.7, 0.9];

fn main() {
    let mut out = Json::obj();
    for model in ["ds-llama-8b", "ds-qwen-7b"] {
        for dataset in ["gsm8k", "math500"] {
            println!("\nFig. 5 — {model} × {dataset}");
            let mut header = vec!["Method".to_string()];
            header.extend(RS.iter().map(|r| format!("r={r:.1}")));
            let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
            let mut t = Table::new(&hrefs);
            let mut panel = Json::obj();
            for policy in ["full", "tova", "h2o", "raas", "rkv", "lazy"] {
                let mut row = vec![policy.to_string()];
                let mut curve: Vec<Json> = Vec::new();
                for r in RS {
                    let mut spec = CellSpec::new(policy, model, dataset, r);
                    spec.n_samples = samples_per_cell().min(16);
                    let a = run_cell(&spec).accuracy;
                    row.push(acc(a));
                    curve.push(Json::obj().set("r", r).set("acc", a));
                }
                t.row(row);
                panel = panel.set(policy, Json::Arr(curve));
            }
            t.print();
            out = out.set(&format!("{model}/{dataset}"), panel);
        }
    }
    let _ = save_results("fig5", out);
}
