//! Table 2: GPQA-Diamond (r=50%) and LiveCodeBench (r=40%) — the
//! low-token-similarity domains where R-KV's redundancy assumption breaks
//! (its accuracy must collapse relative to the math tables) while
//! LazyEviction stays near FullKV.

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::eviction::PAPER_POLICIES;
use lazyeviction::util::json::Json;

fn main() {
    let blocks = [("gpqa", 0.5), ("lcb", 0.4)];
    let models = ["ds-llama-8b", "ds-qwen-7b"];
    let mut out = Json::obj();
    for (dataset, r) in blocks {
        println!("\nTable 2 — {dataset} (r = {:.0}%)", r * 100.0);
        let mut t = Table::new(&["Method", "DS-Llama-8B", "DS-Qwen-7B"]);
        let mut block = Json::obj();
        for policy in PAPER_POLICIES {
            let mut row = vec![policy.to_string()];
            let mut jrow = Json::obj();
            for model in models {
                let mut spec = CellSpec::new(policy, model, dataset, r);
                spec.n_samples = samples_per_cell();
                let cell = run_cell(&spec);
                row.push(acc(cell.accuracy));
                jrow = jrow.set(model, cell.accuracy);
            }
            t.row(row);
            block = block.set(policy, jrow);
        }
        t.print();
        out = out.set(dataset, block);
    }
    let _ = save_results("table2", out);
}
