//! Fig. 2: (a) greedy baselines (H2O/TOVA) keep ~full accuracy on a
//! PG-19-like LM profile but drop hard on GSM8K at the same r=50% — the
//! motivating failure; (b) the top-50%-important token-position grid across
//! decoding steps (importance moves around ⇒ greedy eviction is unsafe),
//! dumped as a JSON series for plotting.

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::Table};
use lazyeviction::kvcache::TokenRecord;
use lazyeviction::trace::generator::generate;
use lazyeviction::trace::workload::{dataset_profile, model_profile};
use lazyeviction::util::json::Json;

fn main() {
    // --- (a) relative accuracy retention at r = 50% -----------------------
    println!("\nFig. 2a — accuracy retention (% of FullKV) at r=50%");
    let mut t = Table::new(&["Method", "pg19-sim (LM)", "gsm8k-sim (reasoning)"]);
    let mut ja = Json::obj();
    for policy in ["h2o", "tova", "lazy"] {
        let mut row = vec![policy.to_string()];
        let mut jrow = Json::obj();
        for dataset in ["pg19", "gsm8k"] {
            let mut spec = CellSpec::new(policy, "ds-llama-8b", dataset, 0.5);
            spec.n_samples = samples_per_cell();
            let cell = run_cell(&spec);
            let retention = 100.0 * cell.accuracy / cell.base_acc;
            row.push(format!("{retention:.1}%"));
            jrow = jrow.set(dataset, retention);
        }
        t.row(row);
        ja = ja.set(policy, jrow);
    }
    t.print();
    println!("(H2O/TOVA must retain ≳95% on LM but lose ~20% on reasoning)");

    // --- (b) top-50% importance positions vs decoding step ----------------
    let wp = dataset_profile("gsm8k");
    let mp = model_profile("ds-llama-8b");
    let tr = generate(&wp, &mp, 1234);
    let mut recs: Vec<TokenRecord> = (0..tr.total_len).map(|p| TokenRecord::new(p, p)).collect();
    let mut grid: Vec<Json> = Vec::new();
    let stride = (tr.steps.len() / 24).max(1);
    let mut moved = 0usize;
    let mut prev_top: Vec<u32> = Vec::new();
    for (si, step) in tr.steps.iter().enumerate() {
        for a in &step.activations {
            let r = &mut recs[a.pos as usize];
            r.cum_attn = r.cum_attn * 0.9 + a.score; // decayed importance
        }
        if si % stride == 0 {
            let live = tr.prompt_len as usize + si;
            let mut idx: Vec<u32> = (0..live as u32).collect();
            idx.sort_unstable_by(|&x, &y| {
                recs[y as usize]
                    .cum_attn
                    .partial_cmp(&recs[x as usize].cum_attn)
                    .unwrap()
            });
            idx.truncate(live / 2);
            if !prev_top.is_empty() {
                moved += idx.iter().filter(|p| !prev_top.contains(p)).count();
            }
            prev_top = idx.clone();
            grid.push(
                Json::obj()
                    .set("step", tr.prompt_len as usize + si)
                    .set("top_positions", idx.iter().map(|&x| x as i64).collect::<Vec<i64>>()),
            );
        }
    }
    println!(
        "Fig. 2b — top-50% set churn: {} position changes across {} snapshots \
         (tokens critical later are absent earlier)",
        moved,
        grid.len()
    );
    let payload = Json::obj().set("fig2a", ja).set("fig2b", Json::Arr(grid));
    let _ = save_results("fig2", payload);
}
