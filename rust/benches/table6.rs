//! Table 6 (App. E.1): per-window computational complexity. We *measure*
//! the score/ranking op counters the policies report during replay and the
//! simulator wall time, and check them against the paper's bounds:
//!   H2O/RaaS  O(W(B + BlogB))   TOVA  O(W·BlogB)
//!   LazyEviction  O(WB + BlogB)  — one ranking per window, not W.

use lazyeviction::bench_harness::simgrid::samples_per_cell;
use lazyeviction::bench_harness::{save_results, table::Table};
use lazyeviction::eviction::{self, PolicyParams};
use lazyeviction::sim::{replay, ReplayConfig};
use lazyeviction::trace::generator::generate;
use lazyeviction::trace::workload::{dataset_profile, model_profile};
use lazyeviction::util::json::Json;

fn main() {
    println!("\nTable 6 — measured eviction-side work per generated window (W=25, B=budget)");
    let wp = dataset_profile("math500");
    let mp = model_profile("ds-qwen-7b");
    let params = PolicyParams { window: 25, recent: 25, ..Default::default() };
    let n = samples_per_cell().min(12);
    let mut t = Table::new(&[
        "Policy",
        "score ops/window",
        "rank ops/window",
        "decisions",
        "sim wall ms/sample",
    ]);
    let mut out = Json::obj();
    for spec in ["h2o", "tova", "raas", "rkv", "lazy"] {
        let policy = eviction::build(spec, &params).unwrap();
        let (mut s_ops, mut r_ops, mut dec, mut wall, mut windows) = (0u64, 0u64, 0usize, 0.0, 0f64);
        for i in 0..n {
            let tr = generate(&wp, &mp, 40_000 + i as u64);
            let budget = (tr.total_len as f64 * 0.5) as usize;
            let cfg = ReplayConfig::new(budget, params.window + 8, mp.alpha);
            let r = replay(&tr, policy.as_ref(), cfg);
            s_ops += r.score_ops;
            r_ops += r.rank_ops;
            dec += r.eviction_decisions;
            wall += r.wall_s;
            windows += tr.steps.len() as f64 / params.window as f64;
        }
        t.row(vec![
            spec.to_string(),
            format!("{:.0}", s_ops as f64 / windows),
            format!("{:.0}", r_ops as f64 / windows),
            format!("{:.1}", dec as f64 / n as f64),
            format!("{:.2}", wall * 1e3 / n as f64),
        ]);
        out = out.set(
            spec,
            Json::obj()
                .set("score_ops_per_window", s_ops as f64 / windows)
                .set("rank_ops_per_window", r_ops as f64 / windows)
                .set("wall_ms_per_sample", wall * 1e3 / n as f64),
        );
    }
    t.print();
    println!("(lazy's rank ops/window must be ~1/W of the greedy baselines')");
    let _ = save_results("table6", out);
}
