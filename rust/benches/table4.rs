//! Table 4: importance-score ablation — drop H1 (recurrence-interval term)
//! or H2 (frequency term) from Eq. 2. Dropping H1 must hurt a lot; H2 a
//! little (paper: −3.95/−5.62 vs −0.39/−1.19 points).

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::eviction::ScoreConfig;
use lazyeviction::util::json::Json;

fn main() {
    println!("\nTable 4 — MRI-centric score ablation (GSM8K, r=50%)");
    let models = ["ds-llama-8b", "ds-qwen-7b"];
    let mut t = Table::new(&["Variant", "DS-Llama-8B", "DS-Qwen-7B"]);
    let variants: [(&str, ScoreConfig); 3] = [
        ("LazyEviction", ScoreConfig::default()),
        ("w/o H1-Score", ScoreConfig { use_h1: false, ..Default::default() }),
        ("w/o H2-Score", ScoreConfig { use_h2: false, ..Default::default() }),
    ];
    let mut out = Json::obj();
    let mut base_row: Vec<f64> = Vec::new();
    for (name, sc) in variants {
        let mut row = vec![name.to_string()];
        let mut jrow = Json::obj();
        for (mi, model) in models.iter().enumerate() {
            let mut spec = CellSpec::new("lazy", model, "gsm8k", 0.5);
            spec.score = Some(sc);
            spec.n_samples = samples_per_cell();
            let a = run_cell(&spec).accuracy;
            if name == "LazyEviction" {
                base_row.push(a);
                row.push(acc(a));
            } else {
                row.push(format!("{} ({:+.2})", acc(a), a - base_row[mi]));
            }
            jrow = jrow.set(*model, a);
        }
        t.row(row);
        out = out.set(name, jrow);
    }
    t.print();
    let _ = save_results("table4", out);
}
