//! Table 1: accuracy on mathematical reasoning (GSM8K r=50%, MATH-500
//! r=50%, AIME r=30%) for 6 methods × 4 model profiles. Simulator tier
//! (DESIGN.md §5.3); the paper's FullKV rows seed the model ceilings.

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::eviction::PAPER_POLICIES;
use lazyeviction::trace::workload::MODELS;
use lazyeviction::util::json::Json;

fn main() {
    let blocks = [("gsm8k", 0.5), ("math500", 0.5), ("aime", 0.3)];
    let mut out = Json::obj();
    for (dataset, r) in blocks {
        println!(
            "\nTable 1 — {dataset} (compression ratio r = {:.0}%)",
            r * 100.0
        );
        let mut t = Table::new(&["Method", "DS-Llama", "DS-Qwen", "Qwen3", "QwQ"]);
        let mut block = Json::obj();
        for policy in PAPER_POLICIES {
            let mut row = vec![display_name(policy)];
            let mut jrow = Json::obj();
            for model in MODELS {
                let mut spec = CellSpec::new(policy, model, dataset, r);
                spec.n_samples = samples_per_cell();
                let cell = run_cell(&spec);
                row.push(acc(cell.accuracy));
                jrow = jrow.set(model, cell.accuracy);
            }
            t.row(row);
            block = block.set(policy, jrow);
        }
        t.print();
        out = out.set(dataset, block);
    }
    let _ = save_results("table1", out);
}

fn display_name(p: &str) -> String {
    match p {
        "full" => "FullKV".into(),
        "raas" => "RaaS".into(),
        "h2o" => "H2O".into(),
        "tova" => "TOVA".into(),
        "rkv" => "R-KV".into(),
        "lazy" => "Ours (LazyEviction)".into(),
        other => other.into(),
    }
}
