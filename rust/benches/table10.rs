//! Table 10 (App. F.2): importance-threshold α sweep on GSM8K, r=50%.
//! Too-small α ⇒ everything "important" every step (MRI collapses to ~1);
//! too-large α ⇒ spikes missed. The per-model optimum sits in between.

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::util::json::Json;

fn main() {
    let sweeps: [(&str, &[f32]); 2] = [
        ("ds-llama-8b", &[1e-4, 5e-4, 1e-3, 5e-2]),
        ("ds-qwen-7b", &[1e-5, 1e-4, 1e-3, 5e-2]),
    ];
    let mut out = Json::obj();
    for (model, alphas) in sweeps {
        println!("\nTable 10 — α sweep ({model}, GSM8K, r=50%)");
        let mut header = vec!["".to_string(), "FullKV".to_string()];
        header.extend(alphas.iter().map(|a| format!("α={a:.0e}")));
        let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&hrefs);

        let mut full_spec = CellSpec::new("full", model, "gsm8k", 0.5);
        full_spec.n_samples = samples_per_cell();
        let full = run_cell(&full_spec).accuracy;

        let mut row = vec!["Acc.".to_string(), acc(full)];
        let mut block = Json::obj().set("full", full);
        for &a in alphas {
            let mut spec = CellSpec::new("lazy", model, "gsm8k", 0.5);
            spec.alpha = Some(a);
            spec.n_samples = samples_per_cell();
            let v = run_cell(&spec).accuracy;
            row.push(acc(v));
            block = block.set(&format!("{a:e}"), v);
        }
        t.row(row);
        t.print();
        out = out.set(model, block);
    }
    let _ = save_results("table10", out);
}
