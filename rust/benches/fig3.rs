//! Fig. 3: Token Importance Recurrence statistics.
//!  (c) MRI distributions (CDF) per model × dataset from simulated traces —
//!      plus, when artifacts exist, the REAL served model's MRI distribution
//!      measured through the trace executable (per-layer/head attention).
//! Prints the >95%-recurrence statistic and the 80th-percentile W rule.

use lazyeviction::bench_harness::{artifacts_available, artifacts_dir, save_results, table::Table};
use lazyeviction::runtime::{Client, Manifest, ModelExecutor};
use lazyeviction::trace::workload::{dataset_profile, gen_reasoning_sample, model_profile, MODELS};
use lazyeviction::trace::{generator, mri};
use lazyeviction::util::json::Json;
use lazyeviction::util::rng::Rng;
use lazyeviction::util::stats;

fn main() -> anyhow::Result<()> {
    println!("\nFig. 3c — MRI distributions (simulated model profiles)");
    let mut t = Table::new(&["model", "dataset", "recur frac", "MRI p50", "MRI p80 (=W)"]);
    let mut out = Json::obj();
    for model in MODELS {
        for dataset in ["gsm8k", "math500"] {
            let wp = dataset_profile(dataset);
            let mp = model_profile(model);
            let traces: Vec<_> =
                (0..6).map(|s| generator::generate(&wp, &mp, 77_000 + s)).collect();
            let mris = mri::measure_mri(&traces, mp.alpha);
            let frac = mri::recurrence_fraction(&traces, mp.alpha);
            let p50 = stats::percentile(&mris, 0.5);
            let p80 = stats::percentile(&mris, 0.8);
            t.row(vec![
                model.into(),
                dataset.into(),
                format!("{:.1}%", frac * 100.0),
                format!("{p50:.0}"),
                format!("{p80:.0}"),
            ]);
            let xs: Vec<f64> = [1., 2., 5., 10., 25., 50., 100., 175., 300., 600.].to_vec();
            let cdf = mri::mri_cdf(&mris, &xs);
            out = out.set(
                &format!("{model}/{dataset}"),
                Json::obj()
                    .set("recur_frac", frac)
                    .set("p50", p50)
                    .set("p80", p80)
                    .set(
                        "cdf",
                        Json::Arr(
                            cdf.iter()
                                .map(|(x, f)| Json::obj().set("mri", *x).set("cdf", *f))
                                .collect(),
                        ),
                    ),
            );
        }
    }
    t.print();

    // ---- real-model MRI via the trace executable -------------------------
    if artifacts_available() {
        let manifest = Manifest::load(artifacts_dir())?;
        let client = Client::cpu()?;
        let mut ex = ModelExecutor::new_trace(&client, &manifest, 512)?;
        let d = ex.dims().clone();
        let tok = lazyeviction::tokenizer::Tokenizer::new(&manifest.charset);
        let mut rng = Rng::new(7);
        let alpha = 5e-4f32;
        let mut mris: Vec<f64> = Vec::new();
        let mut n_tokens = 0usize;
        let mut n_recur = 0usize;
        for si in 0..4u64 {
            let sample = gen_reasoning_sample(&mut rng, 5, 24);
            let ids = tok.encode(&sample.prompt).unwrap();
            let p = ids.len();
            // prefill
            let mut toks = vec![0i32; ex.prefill_bucket];
            let mut valid = vec![0f32; ex.prefill_bucket];
            for (i, &id) in ids.iter().enumerate() {
                toks[i] = id as i32;
                valid[i] = 1.0;
            }
            let pre = ex.prefill(&toks, &valid)?;
            ex.insert(&pre.k_seq, &pre.v_seq, 0)?;
            // decode with full per-layer/head attention export
            let gen_len = 360usize;
            let mut ts = vec![0u32; p + gen_len + 1];
            let mut mri = vec![0u32; p + gen_len + 1];
            for (i, t0) in ts.iter_mut().enumerate().take(p) {
                *t0 = i as u32;
            }
            let mut mask = vec![0f32; 512];
            mask[..p].fill(1.0);
            let mut cur_tok = argmax(&pre.logits_last) as i32;
            let mut live = p;
            let tmpl: Vec<char> = sample.template.chars().collect();
            for s in 0..gen_len {
                let step_t = (p + s) as u32;
                let out = ex.step(&mask, &[cur_tok], &[step_t as i32])?;
                // attn layout [L, H, S]: aggregate mean-over-L of max-over-H
                for slot in 0..live {
                    let mut agg = 0.0f32;
                    for l in 0..d.n_layers {
                        let mut mx = 0.0f32;
                        for h in 0..d.n_heads {
                            mx = mx.max(out.attn[(l * d.n_heads + h) * 512 + slot]);
                        }
                        agg += mx;
                    }
                    agg /= d.n_layers as f32;
                    if agg >= alpha {
                        let interval = step_t - ts[slot];
                        if interval > mri[slot] {
                            mri[slot] = interval;
                        }
                        ts[slot] = step_t;
                    }
                }
                ex.append(&out.k_new, &out.v_new, &[live as i32])?;
                ts[live] = step_t;
                mask[live] = 1.0;
                live += 1;
                if live >= 510 {
                    break;
                }
                // follow the template to keep the generation reasoning-shaped
                let pred = argmax(&out.logits) as i32;
                cur_tok = if (s as usize) < tmpl.len() && tmpl[s as usize] != '?' {
                    tok.id(tmpl[s as usize]).unwrap_or(0) as i32
                } else {
                    pred
                };
            }
            n_tokens += live;
            n_recur += mri[..live].iter().filter(|&&m| m > 1).count();
            mris.extend(mri[..live].iter().filter(|&&m| m > 0).map(|&m| m as f64));
            let _ = si;
        }
        let frac = n_recur as f64 / n_tokens.max(1) as f64;
        let p80 = stats::percentile(&mris, 0.8);
        println!(
            "\nFig. 3 (real served model): {} tokens, recurrence fraction {:.1}%, \
             MRI p50 {:.0}, p80 {:.0} ⇒ suggested W = {:.0}",
            n_tokens,
            frac * 100.0,
            stats::percentile(&mris, 0.5),
            p80,
            p80.max(2.0)
        );
        out = out.set(
            "real_model",
            Json::obj()
                .set("recur_frac", frac)
                .set("p50", stats::percentile(&mris, 0.5))
                .set("p80", p80),
        );
    } else {
        eprintln!("fig3: artifacts missing — real-model MRI section skipped");
    }
    let _ = save_results("fig3", out);
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
