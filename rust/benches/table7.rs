//! Table 7 (App. E.2): single-step decode latency vs position — REAL ENGINE.
//! FullKV's per-step latency grows with generated length; LazyEviction's
//! flattens once the budget caps the live KV. Paper scale 16k/8192 budget is
//! divided by 8 for this testbed: generate 2048 tokens, budget 1024,
//! measuring mean step latency around positions {256, 512, 1024, 1536, 2048}.

use lazyeviction::bench_harness::{artifacts_available, artifacts_dir, save_results, table::Table};
use lazyeviction::coordinator::{Engine, EngineConfig, Request};
use lazyeviction::runtime::{Client, Manifest};
use lazyeviction::util::json::Json;

const CHECKPOINTS: [usize; 5] = [256, 512, 1024, 1536, 2048];

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        eprintln!("table7: artifacts missing — run `make artifacts` (engine bench skipped)");
        return Ok(());
    }
    let manifest = Manifest::load(artifacts_dir())?;
    let client = Client::cpu()?;
    let gen_len = std::env::var("LAZYEVICTION_T7_LEN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000usize); // leave prompt headroom below the 2048 cache

    println!("\nTable 7 — single-step decode latency (ms) vs position, gen={gen_len}, budget=1024");
    let mut t = Table::new(&["Method", "256", "512", "1024", "1536", "2048"]);
    let mut out = Json::obj();
    for (name, policy, budget) in [
        ("FullKV", "full", 2048usize),
        ("LazyEviction", "lazy", 1024),
    ] {
        let mut cfg = EngineConfig {
            batch: 1,
            cache: 2048,
            budget,
            policy: policy.into(),
            record_live: false,
            ..Default::default()
        };
        cfg.params.window = 25;
        cfg.params.recent = 25;
        let mut engine = Engine::new(&client, &manifest, cfg)?;
        // per-step raw series is opt-in now (the default path keeps only a
        // bounded histogram); this bench needs positional windows
        engine.metrics.enable_step_log(gen_len + 64);
        engine.run_all(vec![Request {
            id: 0,
            prompt: "#A=3;B=7;C=2;D=5;\n>".into(),
            template: String::new(),
            max_new: gen_len,
            resume: None,
        }])?;
        let lat = engine.metrics.step_log();
        let mut row = vec![name.to_string()];
        let mut jrow = Json::obj();
        for cp in CHECKPOINTS {
            let cp = cp.min(lat.len());
            let lo = cp.saturating_sub(64);
            let window = &lat[lo..cp];
            let ms = window.iter().sum::<f64>() * 1e3 / window.len().max(1) as f64;
            row.push(format!("{ms:.2}"));
            jrow = jrow.set(&format!("{cp}"), ms);
        }
        t.row(row);
        out = out.set(name, jrow);
        eprintln!(
            "  {name}: evictions in {} decisions, throughput {:.1} tok/s",
            engine.metrics.eviction_count,
            engine.metrics.throughput()
        );
    }
    t.print();
    println!("(FullKV must grow with position; LazyEviction must flatten at the budget)");
    let _ = save_results("table7", out);
    Ok(())
}
