//! Fig. 6: KV-cache memory vs output length (0–8k tokens) for five methods,
//! reported in GB at the paper's 7B model scale. FullKV grows linearly;
//! greedy baselines clamp at the budget; LazyEviction shows the small
//! observation-window sawtooth above the budget. Live-token curves come
//! from simulator replay; the engine's device-byte accounting cross-checks
//! the per-token cost when artifacts are available.

use lazyeviction::bench_harness::{artifacts_available, save_results, table::Table};
use lazyeviction::eviction::{self, PolicyParams};
use lazyeviction::kvcache::memory::KvCost;
use lazyeviction::sim::{replay, ReplayConfig};
use lazyeviction::trace::generator::generate;
use lazyeviction::trace::workload::{dataset_profile, model_profile};
use lazyeviction::util::json::Json;

fn main() -> anyhow::Result<()> {
    let budget = 4096usize;
    let out_len = 8192usize;
    let mut wp = dataset_profile("aime");
    wp.out_len = (out_len, out_len);
    let mp = model_profile("ds-qwen-7b");
    let cost = KvCost::paper_7b();

    println!(
        "\nFig. 6 — KV memory (GB, 7B scale) vs output length, budget {budget} (r=50%)"
    );
    let checkpoints = [1024usize, 2048, 4096, 6144, 8192];
    let mut header = vec!["Method".to_string()];
    header.extend(checkpoints.iter().map(|c| format!("{c}")));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    let mut out = Json::obj();
    for policy_spec in ["full", "tova", "h2o", "raas", "lazy"] {
        let params = PolicyParams { window: 128, recent: 128, ..Default::default() };
        let policy = eviction::build(policy_spec, &params).unwrap();
        let tr = generate(&wp, &mp, 5);
        let mut cfg = ReplayConfig::new(budget, params.window + 8, mp.alpha);
        cfg.record_live = true;
        let r = replay(&tr, policy.as_ref(), cfg);
        let mut row = vec![policy_spec.to_string()];
        let mut curve: Vec<Json> = Vec::new();
        for &cp in &checkpoints {
            let i = cp.min(r.live_curve.len()).saturating_sub(1);
            let gb = cost.bytes_for(r.live_curve[i]) as f64 / 1e9;
            row.push(format!("{gb:.2}"));
        }
        // dense curve for plotting (every 64 steps)
        for (i, &live) in r.live_curve.iter().enumerate().step_by(64) {
            curve.push(
                Json::obj()
                    .set("len", i)
                    .set("gb", cost.bytes_for(live) as f64 / 1e9),
            );
        }
        t.row(row);
        out = out.set(policy_spec, Json::Arr(curve));
    }
    t.print();
    println!("(FullKV linear; bounded methods clamp; lazy fluctuates within W above B)");

    if artifacts_available() {
        // engine-side per-token KV cost cross-check
        let manifest = lazyeviction::runtime::Manifest::load(
            lazyeviction::bench_harness::artifacts_dir(),
        )?;
        let d = &manifest.model;
        let engine_cost = KvCost {
            n_layers: d.n_layers,
            n_heads: d.n_heads,
            d_head: d.d_head,
            dtype_bytes: 4,
        };
        println!(
            "engine cross-check: served model holds {} B per token on device \
             ({} layers × {} heads × {} dims × f32 × K+V)",
            engine_cost.bytes_per_token(),
            d.n_layers,
            d.n_heads,
            d.d_head
        );
    }
    let _ = save_results("fig6", out);
    Ok(())
}
