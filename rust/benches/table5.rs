//! Table 5 (App. D): score-function forms — swap sigmoid for exp/tanh/log/
//! inverse in H1 and H2; all forms should land within a fraction of a point
//! (the paper's point: the *shape* matters, not the exact squashing).
//! Extension: compares the H2 formula as-printed (increasing in MRI) vs the
//! monotone-decreasing reading we default to (DESIGN.md §5 note).

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::eviction::{H2Mode, ScoreConfig, ScoreForm};
use lazyeviction::util::json::Json;

const FORMS: [ScoreForm; 5] = [
    ScoreForm::Sigmoid,
    ScoreForm::Exp,
    ScoreForm::Tanh,
    ScoreForm::Log,
    ScoreForm::Inverse,
];

fn main() {
    let mut out = Json::obj();
    for dataset in ["gsm8k", "math500"] {
        println!("\nTable 5 — score-form sweep ({dataset}, DS-Qwen-7B, r=50%)");
        let mut t = Table::new(&["Form", "H1 swapped", "H2 swapped"]);
        let mut block = Json::obj();
        let run = |sc: ScoreConfig| {
            let mut spec = CellSpec::new("lazy", "ds-qwen-7b", dataset, 0.5);
            spec.score = Some(sc);
            spec.n_samples = samples_per_cell();
            run_cell(&spec).accuracy
        };
        for form in FORMS {
            let h1 = run(ScoreConfig { h1_form: form, ..Default::default() });
            let h2 = run(ScoreConfig { h2_form: form, ..Default::default() });
            t.row(vec![form.name().into(), acc(h1), acc(h2)]);
            block = block.set(
                form.name(),
                Json::obj().set("h1", h1).set("h2", h2),
            );
        }
        // H2-as-printed extension
        let lit = run(ScoreConfig { h2_mode: H2Mode::Literal, ..Default::default() });
        t.row(vec!["h2-as-printed".into(), "-".into(), acc(lit)]);
        block = block.set("h2_literal", lit);
        t.print();
        out = out.set(dataset, block);
    }
    let _ = save_results("table5", out);
}
