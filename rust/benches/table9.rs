//! Table 9 (App. F.1): window-size sweep — accuracy vs W on GSM8K
//! (W ∈ {4..32}) and MATH-500 (W ∈ {8..64}), DS-Llama-8B, r=50%.
//! Shape: accuracy rises with W (more recurrences observed) then dips when
//! the pinned window starts crowding out global tokens.

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::util::json::Json;

fn main() {
    let sweeps: [(&str, &[usize]); 2] = [
        ("gsm8k", &[4, 8, 16, 25, 32]),
        ("math500", &[8, 16, 32, 52, 64]),
    ];
    let mut out = Json::obj();
    for (dataset, ws) in sweeps {
        println!("\nTable 9 — W sweep ({dataset}, DS-Llama-8B, r=50%)");
        let mut header = vec!["".to_string()];
        header.extend(ws.iter().map(|w| format!("W={w}")));
        let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&hrefs);
        let mut row = vec!["Acc.".to_string()];
        let mut block = Json::obj();
        for &w in ws {
            let mut spec = CellSpec::new("lazy", "ds-llama-8b", dataset, 0.5);
            spec.window = Some(w);
            spec.n_samples = samples_per_cell();
            let a = run_cell(&spec).accuracy;
            row.push(acc(a));
            block = block.set(&format!("{w}"), a);
        }
        t.row(row);
        t.print();
        out = out.set(dataset, block);
    }
    let _ = save_results("table9", out);
}
