//! Pool capacity bench — effective batch size under a fixed global block
//! budget, per eviction policy. The serving-scale claim behind the paged-KV
//! subsystem: LazyEviction's lagged compression (live ≈ B+W) frees blocks
//! that admit more concurrent sequences than FullKV (or greedy baselines
//! with looser live sets) under the *same* pool.
//!
//!   cargo bench --bench pool
//!   LAZYEVICTION_BENCH_SAMPLES=48 cargo bench --bench pool   # bigger run
//!
//! Pure simulator path (trace replay + kvpool packing) — no artifacts.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use lazyeviction::bench_harness::{save_results, table::Table};
use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode, Request};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::kvtier::HostTierConfig;
use lazyeviction::scheduler::preempt::crossover_fed_tokens;
use lazyeviction::sim::capacity::{run_capacity, run_fleet, CapacitySpec, FleetRouting, FleetSpec};
use lazyeviction::telemetry::{span, SpanContext, StreamingHistogram, Telemetry};
use lazyeviction::util::json::Json;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::var("LAZYEVICTION_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let base = CapacitySpec::new("lazy", n);
    println!(
        "Pool capacity — {} requests, {} blocks x {} tokens, budget {}, W {} ({}, {})",
        n,
        base.pool.n_blocks,
        base.pool.block_size,
        base.budget,
        base.window,
        base.dataset,
        base.model
    );

    let mut t = Table::new(&[
        "Policy",
        "Sustained batch",
        "Peak batch",
        "Completed",
        "Preemptions",
        "Peak blocks",
    ]);
    let mut out = Json::obj();
    let mut full_mean = 0.0;
    let mut lazy_mean = 0.0;
    for policy in ["full", "h2o", "tova", "rkv", "lazy"] {
        let spec = CapacitySpec::new(policy, n);
        let r = run_capacity(&spec)?;
        if policy == "full" {
            full_mean = r.mean_concurrency;
        }
        if policy == "lazy" {
            lazy_mean = r.mean_concurrency;
        }
        t.row(vec![
            policy.to_string(),
            format!("{:.1}", r.mean_concurrency),
            r.peak_concurrency.to_string(),
            format!("{}/{}", r.completed, n),
            r.preemptions.to_string(),
            format!("{}/{}", r.peak_used_blocks, r.total_blocks),
        ]);
        out = out.set(
            policy,
            Json::obj()
                .set("mean_concurrency", r.mean_concurrency)
                .set("peak_concurrency", r.peak_concurrency)
                .set("completed", r.completed)
                .set("failed", r.failed)
                .set("steps", r.steps as f64)
                .set("preemptions", r.preemptions as f64)
                .set("peak_used_blocks", r.peak_used_blocks),
        );
    }
    t.print();
    if full_mean > 0.0 {
        println!(
            "LazyEviction sustains {:.1}x the FullKV batch under the same budget",
            lazy_mean / full_mean
        );
    }

    // Physical paging payoff #1 — memory. Peak physical KV bytes are bounded
    // by live blocks (and the fixed arena), NOT by batch × max_len: the
    // per-row worst-case buffers this PR removed would have reserved
    // `max_rows` full-cache-size caches regardless of what is live.
    {
        let spec = CapacitySpec::new("lazy", n);
        let r = run_capacity(&spec)?;
        let gb = |b: usize| b as f64 / 1e9;
        println!(
            "\nPhysical KV memory (paper-scale per-token cost, lazy policy)\n\
             \x20 peak live blocks : {:>6.2} GB ({} blocks)\n\
             \x20 paged arena      : {:>6.2} GB ({} blocks)\n\
             \x20 dense per-row    : {:>6.2} GB ({} rows x worst-case cache)\n\
             \x20 arena is {:.1}% of the removed worst case",
            gb(r.peak_kv_bytes),
            r.peak_used_blocks,
            gb(r.arena_kv_bytes),
            r.total_blocks,
            gb(r.dense_kv_bytes),
            spec.max_rows,
            100.0 * r.arena_kv_bytes as f64 / r.dense_kv_bytes as f64
        );
        out = out.set(
            "physical_bytes",
            Json::obj()
                .set("peak_kv_bytes", r.peak_kv_bytes)
                .set("arena_kv_bytes", r.arena_kv_bytes)
                .set("dense_kv_bytes", r.dense_kv_bytes),
        );
        // the acceptance property: physical KV scales with live blocks
        assert!(
            r.peak_kv_bytes <= r.arena_kv_bytes && r.arena_kv_bytes < r.dense_kv_bytes,
            "peak {} <= arena {} < dense {} must hold",
            r.peak_kv_bytes,
            r.arena_kv_bytes,
            r.dense_kv_bytes
        );
    }

    // Physical paging payoff #2 — latency. A full-prompt prefix hit skips
    // the prefill executable outright (the donor's blocks are the data), so
    // repeat-prompt TTFT drops to step latency. Measured over the sim
    // backend: the ratio is architectural (0 prefill executions), the
    // absolute times are illustrative.
    {
        let pool = PoolConfig {
            block_size: 16,
            n_blocks: 64,
            low_watermark: 0,
            high_watermark: 0,
        };
        let cfg = EngineConfig {
            batch: 1,
            cache: 256,
            budget: 192,
            pool: Some(pool),
            ..Default::default()
        };
        let mut e = Engine::new_sim(cfg)?;
        let prompt = "#A=3;B=7;C=2;D=5;E=9;\n>".to_string();
        let reqs = |id| {
            vec![Request {
                id,
                prompt: prompt.clone(),
                template: String::new(),
                max_new: 32,
                resume: None,
            }]
        };
        let cold = e.run_all(reqs(1))?;
        let warm = e.run_all(reqs(2))?;
        e.audit_invariants(&[], true, "prefill-skip drain");
        let prefills = e.exec_counts().prefill;
        println!(
            "\nPrefill-skip scenario — identical prompt twice through one engine\n\
             \x20 cold TTFT {:.3} ms ({} prefill execution), warm TTFT {:.3} ms ({} — skipped)",
            cold[0].metrics.ttft_s * 1e3,
            prefills,
            warm[0].metrics.ttft_s * 1e3,
            e.pool_gauges().map(|g| g.prefix_prefill_skips).unwrap_or(0),
        );
        assert_eq!(prefills, 1, "the repeat prompt must run zero prefills");
        assert_eq!(cold[0].text, warm[0].text, "skip must not change output");
        out = out.set(
            "prefill_skip",
            Json::obj()
                .set("cold_ttft_ms", cold[0].metrics.ttft_s * 1e3)
                .set("warm_ttft_ms", warm[0].metrics.ttft_s * 1e3)
                .set("prefill_executions", prefills as f64),
        );
    }

    // Physical paging payoff #3 — preemption fidelity. A pool tight enough
    // to preempt now costs one bounded recompute prefill per resume instead
    // of full regeneration: resumed rows continue where they stopped with
    // byte-identical output, and the capacity sim quantifies the decode
    // steps recompute-mode resume saves over restart-from-prompt.
    {
        let prompt = "#A=3;B=7;\n>".to_string();
        let mk = |id: u64| Request {
            id,
            prompt: prompt.clone(),
            template: String::new(),
            max_new: 50,
            resume: None,
        };
        // solo baseline: the preemption-free output every resumed row must
        // still reproduce byte-for-byte
        let solo = {
            let mut cfg = EngineConfig {
                batch: 1,
                cache: 64,
                budget: 40,
                pool: None,
                prefix_cache: None,
                ..Default::default()
            };
            cfg.params.window = 8;
            cfg.params.recent = 8;
            Engine::new_sim(cfg)?.run_all(vec![mk(0)])?[0].text.clone()
        };
        // 3 requests through 2 rows over a 9-block pool: two ~6-block rows
        // cannot coexist near their budget, so preemption is guaranteed
        let mut cfg = EngineConfig {
            batch: 2,
            cache: 64,
            budget: 40,
            pool: Some(PoolConfig {
                block_size: 8,
                n_blocks: 9,
                low_watermark: 0,
                high_watermark: 0,
            }),
            ..Default::default()
        };
        cfg.params.window = 8;
        cfg.params.recent = 8;
        let mut e = Engine::new_sim(cfg)?;
        let rs = e.run_all((0..3).map(mk).collect())?;
        e.audit_invariants(&[], true, "preemption-resume drain");
        println!(
            "\nPreemption-resume scenario — 3 requests, 2 rows, 9-block pool\n\
             \x20 preemptions {}, resumes {} (fallbacks {}), recomputed tokens {}",
            e.metrics.preemptions,
            e.metrics.resumes,
            e.metrics.resume_fallbacks,
            e.metrics.recomputed_tokens,
        );
        assert!(e.metrics.preemptions > 0, "the scenario must preempt");
        assert!(
            e.metrics.resumes > 0,
            "preempted rows must resume via recompute, not regenerate"
        );
        assert_eq!(e.metrics.resume_fallbacks, 0, "no resume may fall back here");
        assert!(e.metrics.recomputed_tokens > 0);
        for r in &rs {
            assert_eq!(r.text, solo, "request {}: resumed output diverged", r.id);
            assert_eq!(r.metrics.tokens_out, 50, "request {} cut short", r.id);
        }
        // cost model at fleet scale: restart-from-prompt re-decodes the
        // thrown-away prefix; recompute resume pays one prefill pass instead
        let mut restart = CapacitySpec::new("full", n);
        restart.pool.n_blocks = 64;
        let mut resume = restart.clone();
        resume.recompute_resume = true;
        let a = run_capacity(&restart)?;
        let b = run_capacity(&resume)?;
        assert_eq!(b.restarted_steps, 0);
        assert_eq!(
            a.decode_steps - a.restarted_steps,
            b.decode_steps,
            "recompute must save exactly the restarted decode steps"
        );
        println!(
            "\x20 capacity sim (full policy, 64 blocks): restart re-decoded {} steps;\n\
             \x20 recompute resumed {} times for {} re-prefilled tokens ({} decode steps total vs {})",
            a.restarted_steps, b.resumes, b.recomputed_tokens, b.decode_steps, a.decode_steps,
        );
        out = out.set(
            "preemption_resume",
            Json::obj()
                .set("preemptions", e.metrics.preemptions as f64)
                .set("resumes", e.metrics.resumes as f64)
                .set("recomputed_tokens", e.metrics.recomputed_tokens as f64)
                .set("restart_decode_steps", a.decode_steps as f64)
                .set("restarted_steps", a.restarted_steps as f64)
                .set("recompute_decode_steps", b.decode_steps as f64)
                .set("recompute_prefill_tokens", b.recomputed_tokens as f64),
        );
    }

    // Tiered-KV payoff — demotion/promotion + swap-mode preemption. With
    // the host tier on, eviction parks blocks instead of destroying them;
    // the paper's recurrence phenomenon then shows up as promotions
    // (false evictions avoided) with zero output divergence, and a swap-mode
    // preemption resumes by copying bytes instead of recomputing tokens.
    {
        let tier_cfg = |tier: bool, mode: PreemptMode, batch: usize, blocks: usize| {
            let mut cfg = EngineConfig {
                batch,
                cache: 64,
                budget: 40,
                pool: Some(PoolConfig {
                    block_size: 8,
                    n_blocks: blocks,
                    low_watermark: 0,
                    high_watermark: 0,
                }),
                host_tier: tier.then(|| HostTierConfig { max_bytes: 1 << 20 }),
                preempt_mode: mode,
                ..Default::default()
            };
            cfg.params.window = 8;
            cfg.params.recent = 8;
            cfg
        };
        let mk = |id: u64, max_new: usize| Request {
            id,
            prompt: "#A=3;B=7;\n>".into(),
            template: String::new(),
            max_new,
            resume: None,
        };
        // (a) recurrence-driven promotion on a lazy run, vs a tier-free
        // control of the same config — byte-identical output required
        let control = {
            let mut e = Engine::new_sim(tier_cfg(false, PreemptMode::Recompute, 1, 16))?;
            e.run_all(vec![mk(0, 60)])?[0].text.clone()
        };
        let mut e = Engine::new_sim(tier_cfg(true, PreemptMode::Recompute, 1, 16))?;
        let r = e.run_all(vec![mk(0, 60)])?;
        e.audit_invariants(&[], true, "tier-promotion drain");
        assert_eq!(r[0].text, control, "the tier must not change outputs");
        let m = &e.metrics;
        println!(
            "\nTiered-KV scenario — lazy policy, 1 MiB host tier\n\
             \x20 demoted blocks {}, promotions {}, false evictions avoided {}\n\
             \x20 swap traffic: {} B out, {} B in (tier rejects {})",
            m.demoted_blocks,
            m.promotions,
            m.false_evictions_avoided,
            m.swap_out_bytes,
            m.swap_in_bytes,
            m.tier_rejects,
        );
        assert!(m.demoted_blocks > 0, "evictions must park blocks");
        assert!(
            m.promotions > 0,
            "a recurrence-heavy lazy trace must drive promotions"
        );
        assert!(m.false_evictions_avoided > 0);
        out = out.set(
            "tier",
            Json::obj()
                .set("demoted_blocks", m.demoted_blocks as f64)
                .set("promotions", m.promotions as f64)
                .set("false_evictions_avoided", m.false_evictions_avoided as f64)
                .set("swap_out_bytes", m.swap_out_bytes as f64)
                .set("swap_in_bytes", m.swap_in_bytes as f64),
        );
        // (b) swap-mode preemption: the contended 3-requests/2-rows/9-block
        // scenario again, resumed by byte copies instead of recompute
        let solo = {
            let mut e = Engine::new_sim(tier_cfg(false, PreemptMode::Recompute, 1, 16))?;
            e.run_all(vec![mk(0, 50)])?[0].text.clone()
        };
        let mut e = Engine::new_sim(tier_cfg(true, PreemptMode::Swap, 2, 9))?;
        let rs = e.run_all((0..3).map(|i| mk(i, 50)).collect())?;
        e.audit_invariants(&[], true, "swap-preemption drain");
        for r in &rs {
            assert_eq!(r.text, solo, "request {}: swap resume diverged", r.id);
            assert_eq!(r.metrics.tokens_out, 50);
        }
        assert!(e.metrics.swap_preempts > 0, "the scenario must swap-preempt");
        assert!(e.metrics.resumes > 0);
        assert_eq!(
            e.metrics.recomputed_tokens, 0,
            "swap resumes must not re-prefill"
        );
        println!(
            "\x20 swap-mode preemption: {} swaps, {} resumes, 0 recomputed tokens \
             ({} B moved back in)",
            e.metrics.swap_preempts, e.metrics.resumes, e.metrics.swap_in_bytes,
        );
        // (c) the recompute-vs-swap crossover at fleet scale: identical
        // schedules, one pays tokens, the other pays bytes
        let mut recompute = CapacitySpec::new("full", n);
        recompute.pool.n_blocks = 64;
        recompute.recompute_resume = true;
        let mut swap = recompute.clone();
        swap.recompute_resume = false;
        swap.swap_resume = true;
        let a = run_capacity(&recompute)?;
        let b = run_capacity(&swap)?;
        assert_eq!(a.decode_steps, b.decode_steps, "swap must replay nothing");
        assert_eq!(b.recomputed_tokens, 0);
        assert_eq!(b.swap_in_bytes, b.swap_out_bytes, "tier must drain");
        let live = CapacitySpec::new("lazy", n).budget + CapacitySpec::new("lazy", n).window;
        println!(
            "\x20 capacity sim: recompute re-prefilled {} tokens; swap moved {:.1} MB \
             instead\n\x20 cost model: swap wins past a {}-token fed stream for a \
             lazy live set of ~{} tokens",
            a.recomputed_tokens,
            b.swap_out_bytes as f64 / 1e6 * 2.0,
            crossover_fed_tokens(live),
            live,
        );
        out = out.set(
            "swap_preemption",
            Json::obj()
                .set("recompute_tokens", a.recomputed_tokens as f64)
                .set("swap_out_bytes", b.swap_out_bytes as f64)
                .set("swap_in_bytes", b.swap_in_bytes as f64)
                .set("crossover_fed_tokens", crossover_fed_tokens(live))
                .set("swap_fallbacks", b.swap_fallbacks as f64),
        );
    }

    // Shared-prefix scenario: the same requests behind an identical
    // system-prompt header, served privately (the PR-1 baseline) vs through
    // prefix-cache block sharing. LAZYEVICTION_BENCH_SHARED_PREFIX sets the
    // header length in tokens; values below one block (16) skip the
    // scenario, since nothing can be shared there.
    let header: usize = std::env::var("LAZYEVICTION_BENCH_SHARED_PREFIX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let mut base = CapacitySpec::new("lazy", n);
    // below one block nothing can be shared (run_capacity builds no donor)
    // and the strict shared > private assert would compare a run against
    // itself — skip the scenario rather than panic
    if header >= base.pool.block_size {
        base.shared_prefix_tokens = header;
        base.share_prefix = false;
        let mut shared = base.clone();
        shared.share_prefix = true;
        let b = run_capacity(&base)?;
        let s = run_capacity(&shared)?;
        println!(
            "\nShared-prefix scenario — {header}-token header, lazy policy, same budget"
        );
        let mut t2 = Table::new(&[
            "Header serving",
            "Sustained batch",
            "Peak batch",
            "Completed",
            "Preemptions",
            "Header blocks pinned",
        ]);
        for (label, r) in [("private (PR-1)", &b), ("prefix-shared", &s)] {
            t2.row(vec![
                label.to_string(),
                format!("{:.1}", r.mean_concurrency),
                r.peak_concurrency.to_string(),
                format!("{}/{}", r.completed, n),
                r.preemptions.to_string(),
                r.shared_header_blocks.to_string(),
            ]);
        }
        t2.print();
        println!(
            "prefix sharing sustains {:.2}x the private-header batch",
            s.mean_concurrency / b.mean_concurrency.max(1e-9)
        );
        out = out.set(
            "shared_prefix",
            Json::obj()
                .set("header_tokens", header)
                .set("baseline_mean_concurrency", b.mean_concurrency)
                .set("shared_mean_concurrency", s.mean_concurrency)
                .set("shared_header_blocks", s.shared_header_blocks)
                .set("prefix_forks", s.prefix_forks as f64),
        );
        // the acceptance property this bench exists to witness
        assert!(
            s.mean_concurrency > b.mean_concurrency,
            "shared-prefix batch must strictly exceed the private baseline \
             ({} <= {})",
            s.mean_concurrency,
            b.mean_concurrency
        );
    }

    // Client-abort scenario — cancellation at fleet scale. Every 3rd client
    // disconnects mid-decode (or gives up while swap-parked); the sim must
    // tear the row down and hand its pool blocks — and any pinned tier
    // state — back immediately, leaving no leak at drain. This is the
    // fleet-scale counterpart of the serve loop's EOF → abort path.
    {
        let mut spec = CapacitySpec::new("lazy", n);
        spec.pool.n_blocks = 64;
        spec.abort_every = 3;
        let r = run_capacity(&spec)?;
        println!(
            "\nClient-abort scenario — every 3rd client disconnects mid-decode\n\
             \x20 cancelled {}, completed {}, failed {} (of {})\n\
             \x20 reclaimed {} pool blocks, {} parked tier blocks; {} free at drain",
            r.cancelled,
            r.completed,
            r.failed,
            n,
            r.reclaimed_blocks,
            r.reclaimed_tier_blocks,
            r.end_free_blocks,
        );
        assert_eq!(r.cancelled as usize, n / 3, "every marked client must abort");
        assert_eq!(
            r.cancelled as usize + r.completed + r.failed,
            n,
            "every request must terminate exactly once"
        );
        assert_eq!(
            r.end_free_blocks, r.total_blocks,
            "aborted rows must return their blocks (leak at drain)"
        );
        assert_eq!(r.end_tier_blocks, 0, "no tier state may stay pinned");
        if n >= 3 {
            assert!(r.reclaimed_blocks > 0, "mid-decode aborts must free blocks");
        }
        // swap-mode flavor: clients that give up while parked in the host
        // tier must unpin those bytes at the drop, not at process exit
        let mut swap = CapacitySpec::new("full", n);
        swap.pool.n_blocks = 64;
        swap.swap_resume = true;
        swap.abort_every = 2;
        let s = run_capacity(&swap)?;
        assert_eq!(s.end_tier_blocks, 0, "abandoned parked rows must unpin");
        assert_eq!(s.end_free_blocks, s.total_blocks);
        println!(
            "\x20 swap flavor: {} cancelled, {} parked tier blocks reclaimed, \
             tier empty at drain",
            s.cancelled, s.reclaimed_tier_blocks,
        );
        out = out.set(
            "client_abort",
            Json::obj()
                .set("abort_every", spec.abort_every)
                .set("cancelled", r.cancelled as f64)
                .set("reclaimed_blocks", r.reclaimed_blocks as f64)
                .set("reclaimed_tier_blocks", s.reclaimed_tier_blocks as f64)
                .set("end_tier_blocks", s.end_tier_blocks),
        );
    }

    // Recorded trajectory — BENCH_pool.json. A policy × scenario grid over
    // the sim engine: sustained batch (mean decoding rows per step),
    // TTFT/TPOT percentiles from the engine's streaming histograms, and the
    // tier's promotion/park/shed counters. The `stream` cell re-drives the
    // steady workload serve-loop style and reports client-visible TTFT
    // (submit → first drained token event). `save` schema-checks the report
    // before writing; CI uploads the file as an artifact, so successive
    // runs form a diffable trajectory without parsing bench stdout.
    {
        use lazyeviction::bench_harness::report::{
            BenchReport, BenchScenario, Quantiles, RecurrenceCell,
        };
        let scenario_cfg = |scenario: &str, policy: &str| {
            let (batch, blocks, tier) = match scenario {
                "steady" => (2, 16, false),  // uncontended continuous batching
                "preempt" => (2, 9, false),  // guaranteed preemption (see above)
                _ => (1, 16, true),          // "tier": demote/promote traffic
            };
            let mut cfg = EngineConfig {
                batch,
                cache: 64,
                budget: 40,
                policy: policy.into(),
                pool: Some(PoolConfig {
                    block_size: 8,
                    n_blocks: blocks,
                    low_watermark: 0,
                    high_watermark: 0,
                }),
                host_tier: tier.then(|| HostTierConfig { max_bytes: 1 << 20 }),
                ..Default::default()
            };
            cfg.params.window = 8;
            cfg.params.recent = 8;
            cfg
        };
        let mk = |id: u64, max_new: usize| Request {
            id,
            prompt: "#A=3;B=7;\n>".into(),
            template: String::new(),
            max_new,
            resume: None,
        };
        let mut report = BenchReport::new("pool", n);
        for policy in ["full", "h2o", "tova", "lazy"] {
            // the steady cell's output doubles as the byte-identity baseline
            // for the stream cell below (same config, same requests)
            let mut steady_text: Option<String> = None;
            for scenario in ["steady", "preempt", "tier"] {
                let cfg = scenario_cfg(scenario, policy);
                let peak_batch = cfg.batch;
                let (n_reqs, max_new): (u64, usize) = match scenario {
                    "steady" => (4, 50),
                    "preempt" => (3, 50),
                    _ => (1, 60),
                };
                let mut e = Engine::new_sim(cfg)?;
                let rs = e.run_all((0..n_reqs).map(|id| mk(id, max_new)).collect())?;
                e.audit_invariants(&[], true, "trajectory drain");
                if scenario == "steady" {
                    steady_text = rs.first().map(|r| r.text.clone());
                }
                let m = &e.metrics;
                report.push(BenchScenario {
                    policy: policy.into(),
                    scenario: scenario.into(),
                    steps: m.steps,
                    sustained_batch: if m.steps == 0 {
                        0.0
                    } else {
                        m.tokens_out as f64 / m.steps as f64
                    },
                    peak_batch,
                    completed: m.requests_finished,
                    preemptions: m.preemptions,
                    resumes: m.resumes,
                    promotions: m.promotions,
                    demoted_blocks: m.demoted_blocks,
                    tier_rejects: m.tier_rejects,
                    tier_shed_blocks: e
                        .pool_gauges()
                        .map(|g| g.tier_shed_blocks)
                        .unwrap_or(0),
                    streamed_tokens: m.streamed_tokens,
                    cancelled_rows: m.cancelled_rows,
                    ttft_ms: Quantiles::from_hist(&m.ttft_hist_ms),
                    tpot_ms: Quantiles::from_hist(&m.tpot_hist_ms),
                });
            }

            // "stream": the steady workload re-driven the way the serve loop
            // drives it — submit/step/drain per iteration, with this bench
            // acting as the streaming client. TTFT here is *client-visible*:
            // wall time from submit() to the first drained token event, not
            // the engine-internal prefill clock the other cells report.
            {
                let cfg = scenario_cfg("steady", policy);
                let peak_batch = cfg.batch;
                let (n_reqs, max_new): (u64, usize) = (4, 50);
                let mut e = Engine::new_sim(cfg)?;
                let mut pending: VecDeque<Request> =
                    (0..n_reqs).map(|id| mk(id, max_new)).collect();
                let mut submit_at: HashMap<u64, Instant> = HashMap::new();
                let mut concat: HashMap<u64, String> = HashMap::new();
                let mut ttft = StreamingHistogram::latency_ms();
                let mut streamed: u64 = 0;
                let mut finished: u64 = 0;
                while finished < n_reqs {
                    while !pending.is_empty() && e.has_free_row() {
                        let r = pending.front().expect("nonempty").clone();
                        let (id, fresh) = (r.id, r.resume.is_none());
                        let t0 = Instant::now();
                        if !e.submit(r, 0.0)? {
                            break; // declined under pool pressure; retry
                        }
                        pending.pop_front();
                        if fresh {
                            submit_at.insert(id, t0);
                        }
                    }
                    let done = e.step()?;
                    // tokens drain before terminals, like the serve loop
                    for ev in e.drain_token_events() {
                        streamed += 1;
                        if ev.first {
                            if let Some(t0) = submit_at.get(&ev.req) {
                                ttft.observe(t0.elapsed().as_secs_f64() * 1e3);
                            }
                        }
                        concat.entry(ev.req).or_default().push_str(&ev.text);
                    }
                    for resp in done {
                        finished += 1;
                        let joined = concat.remove(&resp.id).unwrap_or_default();
                        assert_eq!(
                            joined, resp.text,
                            "request {}: streamed concat diverged from the \
                             terminal response",
                            resp.id
                        );
                        if let Some(base) = &steady_text {
                            assert_eq!(
                                &resp.text, base,
                                "request {}: stream drive changed output",
                                resp.id
                            );
                        }
                    }
                    // the steady config should not preempt, but stay
                    // correct if a policy change ever makes it
                    for r in e.take_preempted() {
                        pending.push_front(r);
                    }
                }
                assert_eq!(ttft.n(), n_reqs, "every request must stream a first token");
                e.audit_invariants(&[], true, "stream drain");
                let m = &e.metrics;
                report.push(BenchScenario {
                    policy: policy.into(),
                    scenario: "stream".into(),
                    steps: m.steps,
                    sustained_batch: if m.steps == 0 {
                        0.0
                    } else {
                        m.tokens_out as f64 / m.steps as f64
                    },
                    peak_batch,
                    completed: m.requests_finished,
                    preemptions: m.preemptions,
                    resumes: m.resumes,
                    promotions: m.promotions,
                    demoted_blocks: m.demoted_blocks,
                    tier_rejects: m.tier_rejects,
                    tier_shed_blocks: e
                        .pool_gauges()
                        .map(|g| g.tier_shed_blocks)
                        .unwrap_or(0),
                    streamed_tokens: streamed,
                    cancelled_rows: m.cancelled_rows,
                    ttft_ms: Quantiles::from_hist(&ttft),
                    tpot_ms: Quantiles::from_hist(&m.tpot_hist_ms),
                });
            }
        }

        // Fleet section (schema v2): the multi-replica routing cells. One
        // shared-header workload placed by each routing policy on 3
        // replicas records the affinity-vs-round-robin hit-rate gap, plus
        // affinity at N = 1/2/4 records how sustained batch scales with
        // the fleet. The assertions are the PR's acceptance gate: affinity
        // must strictly beat round-robin on hit rate and at least match it
        // on sustained batch, in the recorded artifact itself.
        {
            use lazyeviction::bench_harness::report::FleetCell;
            let fleet_spec = |replicas: usize, routing: FleetRouting| {
                let mut base = CapacitySpec::new("lazy", n.max(12));
                base.pool.n_blocks = 64;
                let mut f = FleetSpec::new(base, replicas, routing);
                f.header_groups = replicas + 1; // never aligned with i % N
                f.header_tokens = 64;
                f
            };
            let cell = |replicas: usize, routing: FleetRouting| -> anyhow::Result<FleetCell> {
                let spec = fleet_spec(replicas, routing);
                let r = run_fleet(&spec)?;
                Ok(FleetCell {
                    routing: routing.as_str().into(),
                    replicas,
                    sustained_batch: r.sustained_batch,
                    header_hits: r.header_hits,
                    header_misses: r.header_misses,
                    hit_rate: r.hit_rate,
                    preemptions: r.preemptions,
                    completed: r.completed as u64,
                })
            };
            let affinity3 = cell(3, FleetRouting::Affinity)?;
            let rr3 = cell(3, FleetRouting::RoundRobin)?;
            assert!(
                affinity3.hit_rate > rr3.hit_rate,
                "affinity hit rate {} must strictly beat rr {}",
                affinity3.hit_rate,
                rr3.hit_rate
            );
            assert!(
                affinity3.sustained_batch >= rr3.sustained_batch,
                "affinity sustained batch {} must not trail rr {}",
                affinity3.sustained_batch,
                rr3.sustained_batch
            );
            println!("\nfleet routing (3 replicas, shared headers; + affinity scaling)");
            let mut table = Table::new(&[
                "routing",
                "replicas",
                "hit_rate",
                "sustained_batch",
                "preemptions",
            ]);
            for c in [affinity3, rr3] {
                table.row(vec![
                    c.routing.clone(),
                    format!("{}", c.replicas),
                    format!("{:.3}", c.hit_rate),
                    format!("{:.2}", c.sustained_batch),
                    format!("{}", c.preemptions),
                ]);
                report.push_fleet(c);
            }
            let mut prev = 0.0f64;
            for replicas in [1usize, 2, 4] {
                let c = cell(replicas, FleetRouting::Affinity)?;
                assert!(
                    c.sustained_batch >= prev,
                    "sustained batch must be monotone in replica count: \
                     N={replicas} gives {} after {}",
                    c.sustained_batch,
                    prev
                );
                prev = c.sustained_batch;
                table.row(vec![
                    c.routing.clone(),
                    format!("{}", c.replicas),
                    format!("{:.3}", c.hit_rate),
                    format!("{:.2}", c.sustained_batch),
                    format!("{}", c.preemptions),
                ]);
                report.push_fleet(c);
            }
            table.print();
            let cells: Vec<Json> = report.fleet.iter().map(|c| c.to_json()).collect();
            out = out.set("fleet", Json::obj().set("cells", cells));
        }

        // Recurrence section (schema v3): the lazy tier cell re-run with the
        // observatory on, against an identical control with it off. The flag
        // must be output-invariant (same text either way), and a recurrence-
        // heavy lazy trace must record nonzero time-to-promotion samples —
        // the lagged-eviction bet, measured in the artifact itself.
        {
            let mut cfg_on = scenario_cfg("tier", "lazy");
            cfg_on.observe_recurrence = true;
            let mut on = Engine::new_sim(cfg_on)?;
            let r_on = on.run_all(vec![mk(0, 60)])?;
            let mut off = Engine::new_sim(scenario_cfg("tier", "lazy"))?;
            let r_off = off.run_all(vec![mk(0, 60)])?;
            assert_eq!(
                r_on[0].text, r_off[0].text,
                "--observe-recurrence must be output-invariant"
            );
            let obs = on.recurrence().expect("observatory enabled for this cell");
            assert!(obs.passes_total > 0, "the tier cell must evict");
            assert!(
                obs.promotion_hist.n() > 0,
                "the tier cell must record time-to-promotion samples"
            );
            println!(
                "\nrecurrence observatory (lazy, tier cell): {} passes, {} decisions, \
                 {} promotions observed (median parked {:.0} steps)",
                obs.passes_total,
                obs.decisions_total,
                obs.promotion_hist.n(),
                obs.promotion_hist.quantile(0.5),
            );
            report.push_recurrence(RecurrenceCell {
                policy: "lazy".into(),
                scenario: "tier".into(),
                passes: obs.passes_total,
                decisions: obs.decisions_total,
                mri: Quantiles::from_hist(&obs.mri_hist),
                time_to_promotion_steps: Quantiles::from_hist(&obs.promotion_hist),
                postmortem: obs.postmortem,
            });
        }

        // Span trail: the steady lazy cell re-run with telemetry attached,
        // writing the v2 span JSONL CI archives next to BENCH_pool.json. The
        // schema check here is the bench-side gate — a malformed line fails
        // the bench, not a downstream consumer.
        {
            let span_path = std::path::Path::new("BENCH_pool_spans.jsonl");
            std::fs::remove_file(span_path).ok(); // with_trace appends
            let t = Telemetry::with_trace(4096, Some(span_path))?;
            let mut e = Engine::new_sim(scenario_cfg("steady", "lazy"))?;
            e.attach_telemetry(t.clone());
            let reqs: Vec<Request> = (0..4).map(|id| mk(id, 50)).collect();
            let mut roots: HashMap<u64, u64> = HashMap::new();
            for r in &reqs {
                let root = t.span_open(
                    r.id,
                    span::name::REQUEST,
                    SpanContext::default(),
                    None,
                    0.0,
                    "bench",
                );
                e.note_span(r.id, SpanContext::child_of(root, root));
                roots.insert(r.id, root);
            }
            let rs = e.run_all(reqs)?;
            for r in &rs {
                let root = roots.get(&r.id).copied().unwrap_or(0);
                t.span_close_full(
                    root,
                    Some(r.metrics.tokens_out as f64),
                    Some("finished"),
                    false,
                );
            }
            t.flush();
            let stats = span::validate_span_file(span_path)
                .map_err(|err| anyhow::anyhow!("span JSONL failed schema check: {err}"))?;
            assert!(
                stats.opens >= rs.len() as u64 * 2,
                "each request must trace at least a root and a prefill span \
                 ({} opens for {} requests)",
                stats.opens,
                rs.len()
            );
            assert_eq!(stats.opens, stats.closes, "every span must close");
            println!(
                "span trail: {} opens / {} closes / {} flight events -> {}",
                stats.opens,
                stats.closes,
                stats.flight_events,
                span_path.display()
            );
        }
        report.save(std::path::Path::new("BENCH_pool.json"))?;
    }

    save_results("pool", out)?;
    Ok(())
}
