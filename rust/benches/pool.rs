//! Pool capacity bench — effective batch size under a fixed global block
//! budget, per eviction policy. The serving-scale claim behind the paged-KV
//! subsystem: LazyEviction's lagged compression (live ≈ B+W) frees blocks
//! that admit more concurrent sequences than FullKV (or greedy baselines
//! with looser live sets) under the *same* pool.
//!
//!   cargo bench --bench pool
//!   LAZYEVICTION_BENCH_SAMPLES=48 cargo bench --bench pool   # bigger run
//!
//! Pure simulator path (trace replay + kvpool packing) — no artifacts.

use lazyeviction::bench_harness::{save_results, table::Table};
use lazyeviction::sim::capacity::{run_capacity, CapacitySpec};
use lazyeviction::util::json::Json;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::var("LAZYEVICTION_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let base = CapacitySpec::new("lazy", n);
    println!(
        "Pool capacity — {} requests, {} blocks x {} tokens, budget {}, W {} ({}, {})",
        n,
        base.pool.n_blocks,
        base.pool.block_size,
        base.budget,
        base.window,
        base.dataset,
        base.model
    );

    let mut t = Table::new(&[
        "Policy",
        "Sustained batch",
        "Peak batch",
        "Completed",
        "Preemptions",
        "Peak blocks",
    ]);
    let mut out = Json::obj();
    let mut full_mean = 0.0;
    let mut lazy_mean = 0.0;
    for policy in ["full", "h2o", "tova", "rkv", "lazy"] {
        let spec = CapacitySpec::new(policy, n);
        let r = run_capacity(&spec)?;
        if policy == "full" {
            full_mean = r.mean_concurrency;
        }
        if policy == "lazy" {
            lazy_mean = r.mean_concurrency;
        }
        t.row(vec![
            policy.to_string(),
            format!("{:.1}", r.mean_concurrency),
            r.peak_concurrency.to_string(),
            format!("{}/{}", r.completed, n),
            r.preemptions.to_string(),
            format!("{}/{}", r.peak_used_blocks, r.total_blocks),
        ]);
        out = out.set(
            policy,
            Json::obj()
                .set("mean_concurrency", r.mean_concurrency)
                .set("peak_concurrency", r.peak_concurrency)
                .set("completed", r.completed)
                .set("failed", r.failed)
                .set("steps", r.steps as f64)
                .set("preemptions", r.preemptions as f64)
                .set("peak_used_blocks", r.peak_used_blocks),
        );
    }
    t.print();
    if full_mean > 0.0 {
        println!(
            "LazyEviction sustains {:.1}x the FullKV batch under the same budget",
            lazy_mean / full_mean
        );
    }
    save_results("pool", out)?;
    Ok(())
}
