//! Table 8 (App. E.2): average decode latency + throughput — REAL ENGINE.
//! FullKV vs TOVA vs LazyEviction at generation lengths {512, 1024, 2048}
//! (paper's 4k/8k/16k over the ÷8 testbed scale), budget = len/2 (r=50%).
//! The ordering to reproduce: LazyEviction's overhead < TOVA's (lagged vs
//! per-step eviction), and LazyEviction ≥ FullKV at the longest length.

use lazyeviction::bench_harness::{artifacts_available, artifacts_dir, save_results, table::Table};
use lazyeviction::coordinator::{Engine, EngineConfig, Request};
use lazyeviction::runtime::{Client, Manifest};
use lazyeviction::util::json::Json;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        eprintln!("table8: artifacts missing — run `make artifacts` (engine bench skipped)");
        return Ok(());
    }
    let manifest = Manifest::load(artifacts_dir())?;
    let client = Client::cpu()?;
    let lens: Vec<usize> = std::env::var("LAZYEVICTION_T8_LENS")
        .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![512, 1024, 2048]);

    let mut out = Json::obj();
    for gen_len in lens {
        let budget = gen_len / 2;
        println!("\nTable 8 — generation length {gen_len} (budget {budget})");
        let mut t = Table::new(&["Method", "Budget", "Throughput tok/s ↑", "Avg latency ms/tok ↓"]);
        let mut block = Json::obj();
        for (name, policy, b) in [
            ("FullKV", "full", gen_len),
            ("TOVA", "tova", budget),
            ("LazyEviction", "lazy", budget),
        ] {
            let mut cfg = EngineConfig {
                batch: 1,
                cache: 2048,
                budget: b,
                policy: policy.into(),
                record_live: false,
                ..Default::default()
            };
            cfg.params.window = 25;
            cfg.params.recent = 25;
            let mut engine = Engine::new(&client, &manifest, cfg)?;
            engine.run_all(vec![Request {
                id: 0,
                prompt: "#A=3;B=7;C=2;D=5;\n>".into(),
                template: String::new(),
                max_new: gen_len,
                resume: None,
            }])?;
            let thr = engine.metrics.throughput();
            let lat = engine.metrics.avg_latency_ms();
            t.row(vec![
                name.into(),
                if policy == "full" { "-".into() } else { b.to_string() },
                format!("{thr:.2}"),
                format!("{lat:.3}"),
            ]);
            block = block.set(
                name,
                Json::obj().set("throughput", thr).set("avg_latency_ms", lat),
            );
        }
        t.print();
        out = out.set(&format!("len{gen_len}"), block);
    }
    let _ = save_results("table8", out);
    Ok(())
}
