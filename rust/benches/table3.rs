//! Table 3: the observation-window ablation — give H2O/TOVA/RaaS the same
//! lagged mechanics (evict every W, pin recent W) and show they improve but
//! still trail LazyEviction (the MRI score is the remaining gap).
//! GSM8K, DS-Llama-8B, r=50%, W=25 (paper's setting).

use lazyeviction::bench_harness::simgrid::{run_cell, samples_per_cell, CellSpec};
use lazyeviction::bench_harness::{save_results, table::acc, table::Table};
use lazyeviction::util::json::Json;

fn main() {
    println!("\nTable 3 — +window ablation (GSM8K, DS-Llama-8B, r=50%, W=25)");
    let mut t = Table::new(&["Policy", "Accuracy", "Δ vs base"]);
    let mut out = Json::obj();
    let run = |policy: &str| {
        let mut spec = CellSpec::new(policy, "ds-llama-8b", "gsm8k", 0.5);
        spec.window = Some(25);
        spec.n_samples = samples_per_cell();
        run_cell(&spec).accuracy
    };
    let lazy = run("lazy");
    t.row(vec!["LazyEviction".into(), acc(lazy), "-".into()]);
    out = out.set("lazy", lazy);
    for base in ["h2o", "tova", "raas"] {
        let plain = run(base);
        let windowed = run(&format!("{base}+window"));
        t.row(vec![base.to_string(), acc(plain), "-".into()]);
        t.row(vec![
            format!("{base} + window"),
            acc(windowed),
            format!("{:+.2}", windowed - plain),
        ]);
        out = out
            .set(base, plain)
            .set(&format!("{base}+window"), windowed);
    }
    t.print();
    println!("(windowed baselines must improve yet stay below LazyEviction)");
    let _ = save_results("table3", out);
}
