//! Offline shim for the `anyhow` crate: the subset of its API this workspace
//! uses — `Result`/`Error`, the `anyhow!`/`bail!`/`ensure!` macros, and the
//! `Context` extension trait over `Result` and `Option`. The build
//! environment has no registry access, so this path dependency stands in for
//! the real crate; swap the `[dependencies]` entry for crates.io `anyhow`
//! and everything keeps compiling (the API shapes match).

use std::fmt::{self, Display};

/// Drop-in alias matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a human-readable context chain. Like `anyhow::Error`, it
/// deliberately does NOT implement `std::error::Error` — that is what makes
/// the blanket `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
    /// Causes, outermost first (`{:#}` renders `msg: cause: cause`).
    chain: Vec<String>,
}

impl Error {
    /// Build from a displayable message (what `anyhow!` expands to).
    pub fn msg(m: impl Display) -> Error {
        Error {
            msg: m.to_string(),
            chain: Vec::new(),
        }
    }

    /// Build from a standard error, capturing its source chain.
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error {
            msg: e.to_string(),
            chain,
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl Display) -> Error {
        let old = std::mem::replace(&mut self.msg, c.to_string());
        self.chain.insert(0, old);
        self
    }

    /// The context chain, outermost message first.
    pub fn chain_strings(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(|s| s.as_str()))
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Context`: attach context to the error variant of a `Result`, or
/// turn a `None` into an error.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

// Coherent alongside the impl above because `Error` does not implement
// `std::error::Error` (the same trick the real anyhow uses).
impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`: format a message into an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!`: early-return an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!`: early-return an error when the condition is false. With no
/// message, the stringified condition is the message (matching anyhow).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: `{}`",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::new(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: disk on fire");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("present").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn check(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            ensure!(x < 100);
            if x == 13 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert_eq!(format!("{}", check(0).unwrap_err()), "x too small: 0");
        assert_eq!(
            format!("{}", check(200).unwrap_err()),
            "condition failed: `x < 100`"
        );
        assert_eq!(format!("{}", check(13).unwrap_err()), "unlucky");
        let e = anyhow!("plain {}", "fmt");
        assert_eq!(format!("{e}"), "plain fmt");
    }

    #[test]
    fn error_chain_iterates() {
        let e = Error::msg("inner").context("mid").context("outer");
        let chain: Vec<&str> = e.chain_strings().collect();
        assert_eq!(chain, vec!["outer", "mid", "inner"]);
    }
}
