//! Compile-time stub of the `xla` (xla-rs) PJRT API surface used by
//! `lazyeviction::runtime`. The serving environment this workspace builds in
//! has no PJRT shared library, so every entry point that would touch the
//! device reports a clean runtime error instead; the engine layers above
//! gate on artifact availability (tests skip, `Engine::new_sim` serves the
//! artifact-free path). Point the workspace's `xla` path dependency at a
//! real xla-rs checkout to light up the PJRT backend — the type and method
//! shapes here match it.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!("{what}: PJRT runtime not available in this build (xla stub)"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a host buffer / literal can carry.
pub trait ArrayElement: Copy {
    fn wrap(data: &[Self]) -> Elems;
    fn unwrap(e: &Elems) -> Result<Vec<Self>>;
}

/// Type-erased element storage for [`Literal`].
#[derive(Debug, Clone)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Elems {
    fn len(&self) -> usize {
        match self {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
        }
    }
}

impl ArrayElement for f32 {
    fn wrap(data: &[f32]) -> Elems {
        Elems::F32(data.to_vec())
    }
    fn unwrap(e: &Elems) -> Result<Vec<f32>> {
        match e {
            Elems::F32(v) => Ok(v.clone()),
            _ => Err(Error {
                msg: "literal element type mismatch (wanted f32)".into(),
            }),
        }
    }
}

impl ArrayElement for i32 {
    fn wrap(data: &[i32]) -> Elems {
        Elems::I32(data.to_vec())
    }
    fn unwrap(e: &Elems) -> Result<Vec<i32>> {
        match e {
            Elems::I32(v) => Ok(v.clone()),
            _ => Err(Error {
                msg: "literal element type mismatch (wanted i32)".into(),
            }),
        }
    }
}

/// Host-side literal (array or tuple).
#[derive(Debug, Clone)]
pub enum Literal {
    Array { elems: Elems, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        Literal::Array {
            elems: T::wrap(data),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { elems, .. } => {
                let n: i64 = dims.iter().product();
                if n as usize != elems.len() {
                    return Err(Error {
                        msg: format!("reshape: {} elements into dims {:?}", elems.len(), dims),
                    });
                }
                Ok(Literal::Array {
                    elems: elems.clone(),
                    dims: dims.to_vec(),
                })
            }
            Literal::Tuple(_) => Err(Error {
                msg: "cannot reshape a tuple literal".into(),
            }),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => Err(Error {
                msg: "literal is not a tuple".into(),
            }),
        }
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { elems, .. } => T::unwrap(elems),
            Literal::Tuple(_) => Err(Error {
                msg: "cannot to_vec a tuple literal".into(),
            }),
        }
    }
}

/// A PJRT device handle (only named; the upload API takes `Option<&_>`).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A device-resident buffer. In the stub nothing is resident anywhere; the
/// variant exists so upload calls can succeed-shape-check in tests that
/// never execute an executable.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        // scalar upload passes dims = [] with one element
        if !(dims.is_empty() && data.len() == 1) && n != data.len() {
            return Err(Error {
                msg: format!("upload: {} elements for dims {:?}", data.len(), dims),
            });
        }
        Ok(PjRtBuffer {
            literal: Literal::Array {
                elems: T::wrap(data),
                dims: dims.iter().map(|&d| d as i64).collect(),
            },
        })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error {
            msg: format!("HloModuleProto::from_text_file({path}): PJRT runtime not available in this build (xla stub)"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 4);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn upload_shape_checked() {
        let c = PjRtClient;
        assert!(c.buffer_from_host_buffer(&[1i32, 2], &[2], None).is_ok());
        assert!(c.buffer_from_host_buffer(&[7i32], &[], None).is_ok()); // scalar
        assert!(c.buffer_from_host_buffer(&[1i32, 2], &[3], None).is_err());
    }
}
